"""Sharded service plane: routing, wire-format hardening, Zipf workloads,
and cross-shard 2PC atomicity — including under seeded faults.

The atomicity invariant used throughout: each cross-shard MSET ``i`` writes
the same value ``v_i`` to a *dedicated* pair of keys living on different
shards.  After the run drains, a pair must be either fully absent (the
transaction aborted before FINISH(C) — PREPARE never touches the store) or
fully present with equal values.  One-sided presence is a torn transaction
and is asserted against under every fault schedule.
"""

from __future__ import annotations

import pytest

from repro.apps.kvstore import (TXID_LEN, VOTE_CONFLICT, VOTE_OK, KVStoreApp,
                                ShardKVApp, make_txid, mset_req, parse_tprep,
                                rfinish_req, set_req, tdecide_req,
                                tfinish_req, tprep_req, tx_owner_tag)
from repro.core.consensus import ConsensusConfig
from repro.core.substrate import Substrate
from repro.scenario import ScenarioSpec, ServiceSpec, Workload, run_scenario
from repro.service import ShardRouter, ShardedService
from repro.sim.faults import FaultSchedule


def _slow_cfg() -> ConsensusConfig:
    return ConsensusConfig(t=16, window=16, slow_mode="always",
                           ctb_fast_enabled=False, view_timeout_us=20_000.0)


def _service(n_shards=2, seed=7, n_pools=1, cfg=None, **kw):
    sub = Substrate(f_m=1, n_pools=n_pools, seed=seed)
    svc = ShardedService.attach(sub, n_shards=n_shards,
                                cfg=cfg or ConsensusConfig(f=1, f_m=1), **kw)
    return sub, svc


def _cross_pair(svc, tag: int):
    """A (shard0-key, shard1-key) pair dedicated to transaction ``tag``."""
    k0 = next(b"a%d.%d" % (tag, j) for j in range(64)
              if svc.router.shard_of(b"a%d.%d" % (tag, j)) == 0)
    k1 = next(b"b%d.%d" % (tag, j) for j in range(64)
              if svc.router.shard_of(b"b%d.%d" % (tag, j)) == 1)
    return k0, k1


def _assert_not_torn(svc, cl, pairs_by_tag):
    committed = 0
    for tag, (k0, k1) in pairs_by_tag.items():
        v0, _ = svc.run_op(cl, ("get", k0), timeout=5_000_000.0)
        v1, _ = svc.run_op(cl, ("get", k1), timeout=5_000_000.0)
        assert (v0, v1) in ((b"", b""), (b"t%d" % tag, b"t%d" % tag)), (
            f"torn transaction {tag}: {v0!r} vs {v1!r}")
        committed += v0 != b""
    return committed


def _assert_shard_agreement(svc):
    """All live replicas of each shard converged to one app state."""
    for shard in svc.shards:
        snaps = {r.app.snapshot() for r in shard.replicas
                 if not r.crashed and not r.joining}
        assert len(snaps) == 1, f"{shard.name}: divergent replica state"


# --------------------------------------------------------------------------
# Router + wire format
# --------------------------------------------------------------------------
def test_router_is_deterministic_and_total():
    r = ShardRouter(4)
    keys = [b"k%d" % i for i in range(200)]
    assert [r.shard_of(k) for k in keys] == [r.shard_of(k) for k in keys]
    hit = {r.shard_of(k) for k in keys}
    assert hit == {0, 1, 2, 3}
    by_shard = r.split([(k, b"v") for k in keys])
    assert sorted(k for ks in by_shard.values() for k, _ in ks) == sorted(keys)
    with pytest.raises(ValueError):
        ShardRouter(0)


def test_wire_encoders_raise_instead_of_truncating():
    with pytest.raises(ValueError):
        set_req(b"k" * 256, b"v")
    with pytest.raises(ValueError):
        mset_req([(b"k%d" % i, b"v") for i in range(256)])
    with pytest.raises(ValueError):
        mset_req([(b"k", b"v" * 256)])
    with pytest.raises(ValueError):
        mset_req([(b"k" * 256, b"v")])
    # the boundary itself is fine
    assert set_req(b"k" * 255, b"v")[1] == 255
    assert mset_req([(b"k", b"v")] * 255)[1] == 255


def test_apply_rejects_malformed_lengths_deterministically():
    app = KVStoreApp()
    app.apply(set_req(b"good", b"val"))
    # SET whose declared klen overruns the payload
    assert app.apply(b"S" + bytes([40]) + b"short") == b"ERR"
    # MSET truncated mid-pair, count overrun, and trailing garbage
    good = mset_req([(b"m1", b"x"), (b"m2", b"y")])
    assert app.apply(good[:-1]) == b"ERR"
    assert app.apply(b"M" + bytes([3]) + good[2:]) == b"ERR"
    assert app.apply(good + b"junk") == b"ERR"
    # a rejected MSET must not have half-applied
    assert app.apply(b"G" + b"m1") == b""
    assert app.apply(b"G" + b"good") == b"val"
    assert app.apply(b"") == b"ERR"


def test_shard_app_2pc_state_machine():
    app = ShardKVApp()
    tx1, tx2 = make_txid("cli/1", 0, 42), make_txid("cli/2", 0, 777)
    assert len(tx1) == TXID_LEN and tx1 != tx2
    p = tprep_req(tx1, 1000.0, 0, [(b"k", b"v")])
    assert parse_tprep(p) == (tx1, 1000.0, 0, [(b"k", b"v")])
    assert app.apply(p) == VOTE_OK
    assert app.apply(p) == VOTE_OK            # idempotent re-PREPARE
    # conflicting transaction on the locked key loses, and never locks
    assert app.apply(tprep_req(tx2, 1000.0, 0, [(b"k", b"w")])) \
        == VOTE_CONFLICT
    # single-key writes bounce off the lock (no torn overwrite mid-2PC)
    assert app.apply(set_req(b"k", b"z")) == b"LOCKED"
    assert app.apply(mset_req([(b"k", b"z")])) == b"LOCKED"
    # GET still serves the committed (absent) value while pending
    assert app.apply(b"G" + b"k") == b""
    # commit-DECIDE is owner-bound: a non-owner caller (another client, an
    # internal slot, anyone) is refused and records nothing
    assert app.apply(tdecide_req(tx1, b"C")) == b"ERR_NOT_OWNER"
    assert app.apply_from("cli/2", tdecide_req(tx1, b"C")) == b"ERR_NOT_OWNER"
    assert app.apply(b"O" + tx1) == b"NONE"   # refusal left no outcome
    # ...while the owner's commit is recorded; first DECIDE then wins and
    # later ones (any caller) read it back
    assert app.apply_from("cli/1", tdecide_req(tx1, b"C")) == b"OUTC"
    assert app.apply(tdecide_req(tx1, b"A")) == b"OUTC"
    assert app.apply(tfinish_req(tx1, b"C")) == b"OK"
    assert app.apply(b"G" + b"k") == b"v"
    assert app.apply(set_req(b"k", b"z")) == b"OK"   # lock released
    # abort-DECIDE stays open to any caller (recovery probes presume-abort)
    tx3 = make_txid("cli/3", 0, 5)
    assert app.apply(tdecide_req(tx3, b"A")) == b"OUTA"
    # FINISH for the aborted loser is a recorded no-op
    assert app.apply(tfinish_req(tx2, b"A")) == b"OK"
    assert app.apply(tprep_req(tx2, 9000.0, 0, [(b"k", b"w")])) \
        == VOTE_CONFLICT                      # no resurrection after FINISH
    # snapshot/adopt round-trips all six state components
    clone = ShardKVApp()
    clone.adopt(app.snapshot())
    assert clone.snapshot() == app.snapshot()


def test_zipf_workload_keys_are_seeded_and_skewed():
    mk = lambda theta: Workload(kind="closed", n_requests=1, keyspace=40,
                                zipf_theta=theta, key_seed=5,
                                payload_fn=lambda i, k: ("get", k))
    w1, w2 = mk(1.2), mk(1.2)
    keys = [w1.key_for(i) for i in range(600)]
    assert keys == [w2.key_for(i) for i in range(600)]       # seeded
    assert keys[:10] == [w1.key_for(i) for i in range(10)]   # index-stable
    top = max(set(keys), key=keys.count)
    assert keys.count(top) / len(keys) > 3.0 / 40            # skewed
    uni = mk(0.0)
    ukeys = [uni.key_for(i) for i in range(600)]
    assert len(set(ukeys)) > 30                              # spread out
    assert max(ukeys.count(k) for k in set(ukeys)) < 60
    with pytest.raises(ValueError):
        Workload(kind="closed", n_requests=1, keyspace=10)   # no payload_fn


# --------------------------------------------------------------------------
# Service happy path
# --------------------------------------------------------------------------
def test_cross_shard_mset_commits_atomically():
    sub, svc = _service()
    cl = svc.new_client()
    k0, k1 = _cross_pair(svc, 0)
    res, _ = svc.run_op(cl, ("mset", [(k0, b"t0"), (k1, b"t0")]))
    assert res == b"OK"
    assert _assert_not_torn(svc, cl, {0: (k0, k1)}) == 1
    # single-shard mset takes the plain fast path (no 2PC slots)
    res, _ = svc.run_op(cl, ("mset", [(k0, b"x"), (k0 + b"2", b"y")]))
    assert res == b"OK"
    assert svc.run_op(cl, ("get", k0))[0] == b"x"
    sub.sim.run(until=sub.sim.now + 50_000.0)
    _assert_shard_agreement(svc)


def test_conflicting_transactions_serialize_via_locks():
    sub, svc = _service()
    cl_a, cl_b = svc.new_client(), svc.new_client()
    k0, k1 = _cross_pair(svc, 1)
    out = {}
    cl_a.request(("mset", [(k0, b"A"), (k1, b"A")]),
                 lambda r, _l: out.setdefault("a", r))
    cl_b.request(("mset", [(k0, b"B"), (k1, b"B")]),
                 lambda r, _l: out.setdefault("b", r))
    assert sub.sim.run_until(lambda: len(out) == 2, timeout=1_000_000.0)
    assert sorted(out.values()) == [b"ABORTED", b"OK"]
    winner = b"A" if out["a"] == b"OK" else b"B"
    assert svc.run_op(cl_a, ("get", k0))[0] == winner
    assert svc.run_op(cl_a, ("get", k1))[0] == winner


def test_abandoned_transaction_is_presumed_aborted():
    sub, svc = _service(tx_timeout_us=5_000.0)
    cl = svc.new_client()
    k0, k1 = _cross_pair(svc, 2)
    cl.drop_decide = True           # client "crashes" between PREP and DECIDE
    cl.request(("mset", [(k0, b"t2"), (k1, b"t2")]))
    sub.sim.run(until=sub.sim.now + 40_000.0)
    cl.drop_decide = False
    assert _assert_not_torn(svc, cl, {2: (k0, k1)}) == 0
    # locks were released by the recovery FINISH: fresh writes go through
    assert svc.run_op(cl, ("set", k0, b"after"))[0] == b"OK"
    assert svc.run_op(cl, ("set", k1, b"after"))[0] == b"OK"
    _assert_shard_agreement(svc)
    # once the transaction resolved, every recoverer's probe bookkeeping
    # drained — no per-probe state may outlive the probe it served
    assert all(not rec._sigwait and not rec._want_outcome
               for rec in svc.recoveries)
    assert all(not r.app.pending and not r.app.locks
               for shard in svc.shards for r in shard.replicas)


def test_committed_transaction_is_finished_forward():
    sub, svc = _service(tx_timeout_us=5_000.0)
    cl = svc.new_client()
    k0, k1 = _cross_pair(svc, 3)
    cl.drop_finish = True           # client "crashes" after DECIDE(commit)
    cl.request(("mset", [(k0, b"t3"), (k1, b"t3")]))
    sub.sim.run(until=sub.sim.now + 40_000.0)
    cl.drop_finish = False
    # the recorded commit outcome wins: recovery applies, never aborts
    assert _assert_not_torn(svc, cl, {3: (k0, k1)}) == 1
    _assert_shard_agreement(svc)


# --------------------------------------------------------------------------
# Atomicity under seeded faults
# --------------------------------------------------------------------------
def _drive_txs(sub, svc, cl, n_tx, mid_run=None, mid_at=None,
               timeout=5_000_000.0):
    """Issue ``n_tx`` sequential cross-shard MSETs; optionally fire
    ``mid_run()`` at simulated time ``mid_at``.  Returns the key pairs."""
    pairs = {i: _cross_pair(svc, i) for i in range(n_tx)}
    if mid_run is not None:
        sub.sim.at(mid_at, mid_run)
    done = {"n": 0}

    def fire(i):
        if i >= n_tx:
            return
        k0, k1 = pairs[i]

        def cb(_res, _lat):
            done["n"] += 1
            fire(i + 1)

        cl.request(("mset", [(k0, b"t%d" % i), (k1, b"t%d" % i)]), cb)

    fire(0)
    assert sub.sim.run_until(lambda: done["n"] >= n_tx, timeout=timeout), \
        f"2PC stream stalled at {done['n']}/{n_tx}"
    return pairs


def test_participant_leader_crash_mid_2pc():
    """Crash the non-coordinator shard's leader in the middle of the 2PC
    stream: its view change must re-route in-flight PREPARE/FINISH slots;
    no transaction may tear and the stream must finish."""
    sub, svc = _service(cfg=_slow_cfg(), seed=13, n_pools=2,
                        tx_timeout_us=40_000.0)
    cl = svc.new_client()
    leader = svc.shards[1].replicas[0]
    pairs = _drive_txs(sub, svc, cl, n_tx=8,
                       mid_run=leader.crash, mid_at=400.0,
                       timeout=10_000_000.0)
    sub.sim.run(until=sub.sim.now + 200_000.0)
    committed = _assert_not_torn(svc, cl, pairs)
    assert committed == len(pairs)   # crash-faulty leader can't abort them
    leader.recover()
    sub.sim.run(until=sub.sim.now + 200_000.0)
    _assert_shard_agreement(svc)


def test_equivocating_coordinator_leader_mid_2pc():
    """The coordinator shard's Byzantine leader equivocates one slot below
    CTBcast while cross-shard transactions are in flight: non-equivocation
    must hold (one variant survives everywhere) and no transaction tears."""
    sub, svc = _service(cfg=_slow_cfg(), seed=17, n_pools=2,
                        tx_timeout_us=40_000.0)
    cl = svc.new_client()
    leader = svc.shards[0].replicas[0]

    def equivocate():
        v, s, k = leader.view, leader.next_slot, leader.my_ctb.next_k
        m_a = ("PREPARE", v, s, (("evil", s), "", b""))
        m_b = ("PREPARE", v, s, (("evil", s), "", b"\x01"))
        stream = leader.my_ctb._s_lock
        leader.tb.broadcast(stream, k, m_a,
                            [leader.pid, svc.shards[0].replicas[1].pid])
        leader.tb.broadcast(stream, k, m_b,
                            [svc.shards[0].replicas[2].pid])
        leader.my_ctb.buf[k] = m_a
        leader.my_ctb.next_k = max(leader.my_ctb.next_k, k + 1)
        leader.ctb_k = max(leader.ctb_k, k + 1)
        leader.next_slot = s + 1
        leader.my_ctb.escalate(k)

    pairs = _drive_txs(sub, svc, cl, n_tx=6,
                       mid_run=equivocate, mid_at=300.0,
                       timeout=10_000_000.0)
    sub.sim.run(until=sub.sim.now + 200_000.0)
    committed = _assert_not_torn(svc, cl, pairs)
    assert committed == len(pairs)
    _assert_shard_agreement(svc)


def test_pool_reconfiguration_during_prepare():
    """A memory node under the shared slow-path registers dies and its pool
    reconfigures while PREPAREs are in flight: the register quorums shift
    under the 2PC stream without tearing anything."""
    sub, svc = _service(cfg=_slow_cfg(), seed=19, n_pools=2,
                        tx_timeout_us=40_000.0)
    cl = svc.new_client()

    def kill_and_reconfigure():
        sub.sim.processes["m1"].crash()
        sub.sim.after(1_000.0, lambda: sub.pools[0].reconfigure("m1"))

    pairs = _drive_txs(sub, svc, cl, n_tx=8,
                       mid_run=kill_and_reconfigure, mid_at=350.0,
                       timeout=10_000_000.0)
    sub.sim.run(until=sub.sim.now + 200_000.0)
    assert len(sub.pools[0].reconfigurations) >= 1
    committed = _assert_not_torn(svc, cl, pairs)
    assert committed == len(pairs)
    _assert_shard_agreement(svc)


# --------------------------------------------------------------------------
# Byzantine clients / replicas against the 2PC plane (REVIEW hardening)
# --------------------------------------------------------------------------
def test_txids_are_owner_tagged_and_client_separated():
    assert tx_owner_tag("kv/c0") != tx_owner_tag("kv/c1")
    t = make_txid("kv/c0", 3, 99)
    assert len(t) == TXID_LEN and t[:8] == tx_owner_tag("kv/c0")
    assert make_txid("kv/c0", 3, 99) != make_txid("kv/c0", 3, 100)
    # distinct service clients draw from distinct nonce streams
    _sub, svc = _service()
    a, b = svc.new_client(), svc.new_client()
    assert a._tx_rng.getrandbits(64) != b._tx_rng.getrandbits(64)


def test_request_rid_must_match_sender():
    """REQ ingress authentication: a client cannot submit a request under
    another client's rid — the basis of the DECIDE owner-binding."""
    sub, svc = _service()
    shard = svc.shards[0]
    c1, c2 = shard.new_client(), shard.new_client()
    r0 = shard.replicas[0]
    forged = ((c1.pid, 77), set_req(b"zz", b"evil"))
    for pid in shard.replica_pids:
        c2.send(pid, "REQ", forged)
    sub.sim.run(until=sub.sim.now + 30_000.0)
    assert (c1.pid, 77) not in r0.pending_req
    assert all(r.app.store.get(b"zz") is None for r in shard.replicas)
    # the same rid from its real owner is served normally
    box = {}
    c1.request(set_req(b"zz", b"mine"), lambda res, _l: box.update(r=res))
    assert sub.sim.run_until(lambda: "r" in box, timeout=1_000_000.0)
    assert box["r"] == b"OK"


def test_forged_commit_decide_cannot_tear_honest_transaction():
    """The REVIEW's headline attack: a Byzantine client pre-sends
    DECIDE(commit) for an honest client's upcoming txid (worst case: the
    adversary somehow knows the txid, nonce included), then a participant
    votes CONFLICT.  The commit must be refused — the honest client's
    DECIDE(abort) finds no recorded outcome, records the abort, and
    nothing tears."""
    import random as _random

    sub, svc = _service(tx_timeout_us=10_000.0)
    cl, rogue, blocker = svc.new_client(), svc.new_client(), svc.new_client()
    k0, k1 = _cross_pair(svc, 0)
    # the adversary predicts the honest client's next txid exactly
    peek = _random.Random()
    peek.setstate(cl._tx_rng.getstate())
    txid = make_txid(cl.shard_clients[0].pid, 0, peek.getrandbits(64))
    # pre-send DECIDE(C) to the coordinator shard: refused, nothing recorded
    box = {}
    rogue.shard_clients[0].request(tdecide_req(txid, b"C"),
                                   lambda res, _l: box.update(r=res))
    assert sub.sim.run_until(lambda: "r" in box, timeout=1_000_000.0)
    assert box["r"] == b"ERR_NOT_OWNER"
    assert all(txid not in r.app.outcomes for r in svc.shards[0].replicas)
    # force a CONFLICT vote on shard 1: blocker holds k1's lock mid-2PC
    kb, _ = _cross_pair(svc, 9)
    blocker.drop_decide = True
    blocker.request(("mset", [(k1, b"B"), (kb, b"B")]))
    sub.sim.run(until=sub.sim.now + 2_000.0)
    # the honest MSET must abort cleanly — never read back the forged C
    out = {}
    cl.request(("mset", [(k0, b"t0"), (k1, b"t0")]),
               lambda res, _l: out.update(r=res))
    assert sub.sim.run_until(lambda: "r" in out, timeout=1_000_000.0)
    assert out["r"] == b"ABORTED"
    assert svc.shards[0].replicas[0].app.outcomes.get(txid) == b"A"
    assert _assert_not_torn(svc, cl, {0: (k0, k1)}) == 0
    sub.sim.run(until=sub.sim.now + 50_000.0)   # blocker tx presumed-aborted
    _assert_shard_agreement(svc)


def test_byzantine_leader_cannot_forge_recovery_finish():
    """A Byzantine participant-shard leader proposes a recovery FINISH(C)
    with a garbage outcome certificate while the real outcome is still
    undecided.  Honest replicas must refuse to certify the slot (the
    certificate does not verify), the leader loses its view, and recovery
    aborts the transaction — no partial commit."""
    sub, svc = _service(cfg=_slow_cfg(), seed=37, n_pools=2,
                        tx_timeout_us=40_000.0)
    cl = svc.new_client()
    k0, k1 = _cross_pair(svc, 6)
    cl.drop_decide = True           # outcome never decided by the client
    cl.request(("mset", [(k0, b"t6"), (k1, b"t6")]))
    shard = svc.shards[1]
    lead = shard.replicas[0]
    assert sub.sim.run_until(lambda: bool(lead.app.pending),
                             timeout=1_000_000.0)
    txid = next(iter(lead.app.pending))
    fake_cert = tuple((pid, b"\x00" * 64)
                      for pid in svc.shards[0].replica_pids[:2])
    lead._enqueue_proposal((("svc", "tfin", txid, b"C"), "",
                            rfinish_req(txid, b"C", fake_cert)))
    sub.sim.run(until=sub.sim.now + 250_000.0)
    cl.drop_decide = False
    # the forged commit never executed anywhere; presumed-abort won
    assert all(r.app.finished.get(txid) == b"A" for r in shard.replicas)
    assert svc.shards[0].replicas[0].app.outcomes.get(txid) == b"A"
    assert _assert_not_torn(svc, cl, {6: (k0, k1)}) == 0
    assert svc.run_op(cl, ("set", k1, b"after"))[0] == b"OK"
    _assert_shard_agreement(svc)


def test_recovery_survives_replacing_prepared_replicas():
    """REVIEW medium: after a PREPARE locks keys, every replica that
    executed it is replaced or crashed.  The joiners — armed from their
    adopted snapshots via the replace/activation hooks — must still run
    presumed-abort recovery and release the locks."""
    sub, svc = _service(cfg=_slow_cfg(), seed=41, n_pools=2,
                        tx_timeout_us=60_000.0)
    cl = svc.new_client()
    k0, k1 = _cross_pair(svc, 7)
    cl.drop_decide = True
    cl.request(("mset", [(k0, b"t7"), (k1, b"t7")]))
    shard = svc.shards[1]
    assert sub.sim.run_until(
        lambda: sum(1 for r in shard.replicas if r.app.pending) == 3,
        timeout=1_000_000.0)
    # replace two replicas in sequence (one replacement in flight at a time)
    shard.replicas[1].crash()
    j1 = shard.replace_replica(shard.replicas[1].pid)
    assert j1 is not None
    assert sub.sim.run_until(lambda: not j1.joining, timeout=3_000_000.0)
    shard.replicas[2].crash()
    j2 = shard.replace_replica(shard.replicas[2].pid)
    assert j2 is not None
    assert sub.sim.run_until(lambda: not j2.joining, timeout=3_000_000.0)
    # the last original executor of the PREPARE dies: only the joiners'
    # snapshot-adopted recovery timers can release the locks now
    shard.replicas[0].crash()
    sub.sim.run(until=sub.sim.now + 400_000.0)
    cl.drop_decide = False
    assert _assert_not_torn(svc, cl, {7: (k0, k1)}) == 0
    assert all(not r.app.pending and not r.app.locks
               for r in shard.replicas if not r.crashed)
    assert svc.run_op(cl, ("set", k1, b"after"))[0] == b"OK"


def test_scenario_spec_with_seeded_fault_schedule():
    """Declarative end-to-end: a 2-shard ServiceSpec under a Zipf-keyed
    MSET workload with a seeded participant-replica crash+recover, driven
    through run_scenario — the full ISSUE 6 stack in one spec."""
    def op(i, key):
        if i % 3 == 2:
            return ("mset", [(key, b"m%d" % i), (key + b"~", b"m%d" % i)])
        return ("set", key, b"v%d" % i)

    sched = (FaultSchedule()
             .add(600.0, "crash", "kv/s1/r1")
             .add(9_000.0, "recover", "kv/s1/r1"))
    spec = ScenarioSpec(
        apps=[], n_pools=2, seed=23, faults=sched, drain_us=120_000.0,
        services=[ServiceSpec(
            name="kv", n_shards=2, cfg=_slow_cfg(), tx_timeout_us=40_000.0,
            workload=Workload(kind="closed", n_requests=24, n_clients=2,
                              keyspace=32, zipf_theta=0.9, key_seed=29,
                              payload_fn=op, timeout_us=120_000_000.0))])
    res = run_scenario(spec)
    ar = res.apps["kv"]
    assert ar.completed == 24
    assert not res.budget_overruns
    svc = res.substrate.services["kv"]
    # every key must agree with its MSET twin (same tag or both absent)
    cl = svc.new_client()
    store_keys = set()
    for shard in svc.shards:
        store_keys |= set(shard.replicas[0].app.store)
    for k in store_keys:
        if k.endswith(b"~"):
            base = k[:-1]
            v0, _ = svc.run_op(cl, ("get", base), timeout=5_000_000.0)
            v1, _ = svc.run_op(cl, ("get", k), timeout=5_000_000.0)
            # the twin is only ever written by the MSET that wrote base —
            # but base may be overwritten later by a plain SET
            assert v1 != b"" and (v0 == v1 or v0.startswith(b"v")), (base, v0, v1)
    # push both shards past a checkpoint boundary so the recovered replica
    # adopts the post-crash state, then require *strict* convergence
    k0, k1 = _cross_pair(svc, 99)
    for j in range(2 * _slow_cfg().window + 4):
        svc.run_op(cl, ("set", k0 if j % 2 else k1, b"c%d" % j),
                   timeout=5_000_000.0)
    res.substrate.sim.run(until=res.substrate.sim.now + 100_000.0)
    _assert_shard_agreement(svc)


# --------------------------------------------------------------------------
# Live shard split / merge (ISSUE 7)
# --------------------------------------------------------------------------
def test_router_split_and_merge_refine_the_table():
    r = ShardRouter(2)
    keys = [b"k%03d" % i for i in range(300)]
    before = {k: r.shard_of(k) for k in keys}
    rng = r.peek_split(0)
    assert r.commit_split(0, 2) == rng and r.epoch == 1
    assert r.n_shards == 3
    for k in keys:
        after = r.shard_of(k)
        if after == 2:
            assert before[k] == 0      # only shard 0's keys moved
        else:
            assert after == before[k]  # everyone else kept their home
    assert [k for k in keys if r.shard_of(k) == 2], "split moved nothing"
    # merging the new shard back restores the original binary partition
    r.commit_merge(2, 0)
    assert r.epoch == 2 and r.n_shards == 2
    assert {k: r.shard_of(k) for k in keys} == before
    assert sorted(r.table) == [(2, 0), (2, 1)]   # siblings coalesced


def test_split_moves_range_and_preserves_every_key():
    sub, svc = _service(n_shards=2, seed=31)
    cl = svc.new_client()
    keys = [b"k%03d" % i for i in range(40)]
    for k in keys:
        assert svc.run_op(cl, ("set", k, b"v-" + k))[0] == b"OK"
    before = {k: svc.router.shard_of(k) for k in keys}
    done = {}
    new_idx = svc.split_shard(0, when_done=lambda: done.setdefault(
        "t", sub.sim.now))
    assert sub.sim.run_until(lambda: "t" in done, timeout=5_000_000.0), \
        "split never completed"
    assert svc.router.epoch == 1 and svc.router.n_shards == 3
    assert len(svc.reshards) == 1 and svc.reshards[0][1] == "split"
    # every key still readable with its exact value, wherever it now lives
    for k in keys:
        assert svc.run_op(cl, ("get", k))[0] == b"v-" + k
    moved = [k for k in keys if svc.router.shard_of(k) == new_idx]
    assert moved and all(before[k] == 0 for k in moved)
    # the source really dropped the range (no stale shadow copy) and
    # answers MOVED deterministically for it
    src_app = svc.shards[0].replicas[0].app
    assert not any(k in src_app.store for k in moved)
    assert src_app.handoff and not src_app.moving and not src_app.outbound
    # fresh writes land at the new home and are durable there
    for k in moved[:3]:
        assert svc.run_op(cl, ("set", k, b"w2"))[0] == b"OK"
        assert svc.run_op(cl, ("get", k))[0] == b"w2"
    assert any(k in svc.shards[new_idx].replicas[0].app.store
               for k in moved)
    sub.sim.run(until=sub.sim.now + 50_000.0)
    _assert_shard_agreement(svc)


def test_merge_returns_ranges_and_retires_source_shard():
    sub, svc = _service(n_shards=2, seed=33)
    cl = svc.new_client()
    keys = [b"k%03d" % i for i in range(40)]
    for k in keys:
        assert svc.run_op(cl, ("set", k, b"v-" + k))[0] == b"OK"
    done = {}
    svc.merge_shards(1, 0, when_done=lambda: done.setdefault(
        "t", sub.sim.now))
    assert sub.sim.run_until(lambda: "t" in done, timeout=5_000_000.0), \
        "merge never completed"
    assert svc.router.n_shards == 1 and svc.router.epoch == 1
    assert svc.retired == {1} and svc.shards[1].retired
    for k in keys:
        assert svc.router.shard_of(k) == 0
        assert svc.run_op(cl, ("get", k))[0] == b"v-" + k
    # a retired shard takes no fresh traffic but stays attached
    assert len(svc.shards) == 2
    for k in keys[:4]:
        assert svc.run_op(cl, ("set", k, b"w2"))[0] == b"OK"
    assert all(k in svc.shards[0].replicas[0].app.store for k in keys)
    sub.sim.run(until=sub.sim.now + 50_000.0)
    _assert_shard_agreement(svc)


def test_split_then_merge_back_roundtrip():
    """A range that leaves and comes back: split 0 -> new shard, then
    merge the new shard straight back into 0.  The source's stale
    ``handoff`` marker from the split's cut must be cleared by the
    re-adoption — a roundtripped range that keeps answering MOVED (to a
    now-retired shard) strands every key in it."""
    sub, svc = _service(n_shards=2, seed=35)
    cl = svc.new_client()
    keys = [b"k%03d" % i for i in range(30)]
    for k in keys:
        assert svc.run_op(cl, ("set", k, b"v-" + k))[0] == b"OK"
    done = {}
    new = svc.split_shard(0, when_done=lambda: done.setdefault(
        "s", sub.sim.now))
    assert sub.sim.run_until(lambda: "s" in done, timeout=5_000_000.0), \
        "split never completed"
    svc.merge_shards(new, 0, when_done=lambda: done.setdefault(
        "m", sub.sim.now))
    assert sub.sim.run_until(lambda: "m" in done, timeout=5_000_000.0), \
        "merge never completed"
    assert svc.router.epoch == 2 and svc.retired == {new}
    # every key is readable and writable at its (restored) home again
    for k in keys:
        assert svc.run_op(cl, ("get", k))[0] == b"v-" + k
    for k in keys[:6]:
        assert svc.run_op(cl, ("set", k, b"w-" + k))[0] == b"OK"
        assert svc.run_op(cl, ("get", k))[0] == b"w-" + k
    # the restored owner holds no stale MOVED marker for the range
    for rep in svc.shards[0].replicas:
        assert not rep.app.handoff and not rep.app.moving
    sub.sim.run(until=sub.sim.now + 50_000.0)
    _assert_shard_agreement(svc)


def test_split_races_cross_shard_msets_without_tearing():
    """The headline race: a split of the coordinator shard fires in the
    middle of a cross-shard MSET stream.  Transactions prepared under the
    old participant set must finish under it (the freeze drains them),
    later ones bounce and abort cleanly — and no key pair is ever
    GET-observable torn across the router epoch bump."""
    sub, svc = _service(cfg=_slow_cfg(), seed=43, n_pools=2,
                        tx_timeout_us=40_000.0)
    cl = svc.new_client()
    pairs = _drive_txs(sub, svc, cl, n_tx=8,
                       mid_run=lambda: svc.split_shard(0), mid_at=500.0,
                       timeout=20_000_000.0)
    assert sub.sim.run_until(lambda: bool(svc.reshards),
                             timeout=20_000_000.0), "split never completed"
    sub.sim.run(until=sub.sim.now + 200_000.0)
    assert svc.router.epoch == 1 and len(svc.shards) == 3
    _assert_not_torn(svc, cl, pairs)
    _assert_shard_agreement(svc)
    # the split-off range's keys are served at exactly one shard
    src_app = svc.shards[0].replicas[0].app
    new_app = svc.shards[2].replicas[0].app
    assert not (set(src_app.store) & set(new_app.store))


def test_leader_crash_during_split_still_completes():
    """Crash the source shard's leader while the freeze/capture slots are
    in flight: the view change must re-route the reshard slots like any
    pending request and the split must still complete without losing a
    key."""
    sub, svc = _service(cfg=_slow_cfg(), seed=47, n_pools=2,
                        tx_timeout_us=40_000.0)
    cl = svc.new_client()
    keys = [b"k%03d" % i for i in range(24)]
    for k in keys:
        assert svc.run_op(cl, ("set", k, b"v-" + k),
                          timeout=5_000_000.0)[0] == b"OK"
    leader = svc.shards[0].replicas[0]
    t0 = sub.sim.now
    sub.sim.at(t0 + 100.0, lambda: svc.split_shard(0))
    sub.sim.at(t0 + 300.0, leader.crash)
    assert sub.sim.run_until(lambda: bool(svc.reshards),
                             timeout=30_000_000.0), \
        "split stalled on the crashed leader"
    for k in keys:
        assert svc.run_op(cl, ("get", k),
                          timeout=5_000_000.0)[0] == b"v-" + k
    leader.recover()
    sub.sim.run(until=sub.sim.now + 300_000.0)
    _assert_shard_agreement(svc)


def test_reshard_rides_the_fault_schedule():
    """``reshard`` is a first-class FaultEvent: a mid-run hot-shard split
    driven declaratively through run_scenario, under a Zipf-keyed SET
    workload."""
    sched = FaultSchedule().add(1_500.0, "reshard", ("kv", "split", 0))
    spec = ScenarioSpec(
        apps=[], n_pools=2, seed=53, faults=sched, drain_us=200_000.0,
        services=[ServiceSpec(
            name="kv", n_shards=2, cfg=_slow_cfg(), tx_timeout_us=40_000.0,
            workload=Workload(kind="closed", n_requests=30, n_clients=2,
                              keyspace=32, zipf_theta=1.2, key_seed=59,
                              payload_fn=lambda i, k: ("set", k, b"v%d" % i),
                              timeout_us=120_000_000.0))])
    res = run_scenario(spec)
    assert res.apps["kv"].completed == 30
    svc = res.substrate.services["kv"]
    assert res.injector is not None and \
        ("reshard" in {a for (_t, a, _x) in res.injector.log})
    assert len(svc.shards) == 3 and svc.router.epoch == 1
    assert svc.reshards and svc.reshards[0][1] == "split"
    _assert_shard_agreement(svc)
