"""Replicated inference serving: consensus overhead + SLO-aware admission.

Two arms over the same roofline-costed token server (toy-1b: 1e9 params,
26 KiB of KV per token, batch 32 → ~695 µs per 16-prompt/8-decode
request, ~1.4 krps of serial decode capacity):

* **steady** — a comfortable open-loop Poisson load replayed against the
  uBFT-replicated plane AND the unreplicated RPC baseline (both running
  the identical serial decode engine).  The gate is the ISSUE's ≤2×
  bound: at p50 the consensus rounds must cost less than one extra
  service time.
* **flash** — an LLM session workload whose arrival process is a flash
  crowd (base 300 rps → 4 krps, ~3× the decode capacity).  Replayed
  twice: with SLO-sized admission (queue-depth horizon = deadline /
  per-request cost, sheds carry the agreed deterministic BUSY reply) and
  without.  The gates: the admission arm's *served* p99 stays inside the
  3 ms deadline and its SLO attainment beats the no-admission arm, while
  the no-admission arm's tail collapses (p99 ≥ 2× deadline — every
  request is eventually served, minutes of queueing late).

Usage:  PYTHONPATH=src:. python benchmarks/inference.py [--smoke]
"""

from __future__ import annotations

import json
import sys

import numpy as np

from benchmarks.common import emit, percentiles, tune_runtime
from repro.baselines.unreplicated import build_unreplicated
from repro.core.consensus import ConsensusConfig
from repro.runtime.server import TokenServerApp
from repro.serve import (InferencePlane, ServingCostModel, SLOSpec,
                         greedy_decode_fn)
from repro.workloads import flash_crowd_times, llm_session_trace, poisson_times

# toy-1b: measured-shape roofline model, numpy-only (no JAX import — the
# CI smoke job runs with pytest+numpy alone)
N_PARAMS = 1.0e9
KV_BYTES_PER_TOKEN = 26_624
BATCH = 32

DEADLINE_US = 3_000.0
STEADY_RATE_RPS = 600.0        # ~0.42 of serial decode capacity
STEADY_N = 200
SMOKE_STEADY_N = 60
PROMPT, DECODE = 16, 8

FLASH_BASE_RPS = 300.0
FLASH_PEAK_RPS = 4_000.0
FLASH_T_START_US = 20_000.0
FLASH_RAMP_US = 5_000.0
FLASH_HOLD_US = 10_000.0
FLASH_DECAY_US = 5_000.0
FLASH_DURATION_US = 60_000.0
FLASH_SEED = 7


def _cost_model() -> ServingCostModel:
    return ServingCostModel.from_counts("toy-1b", n_params=N_PARAMS,
                                        kv_bytes_per_token=KV_BYTES_PER_TOKEN,
                                        batch=BATCH)


def _serving_cfg(view_timeout_us: float = 200_000.0) -> ConsensusConfig:
    return ConsensusConfig(f=1, t=16, window=32, max_batch=8,
                           pipeline_depth=8, view_timeout_us=view_timeout_us,
                           max_request_bytes=4096)


def _steady_trace(n: int, seed: int = 3):
    """Fixed-shape requests, one session each (ctx=0: every request costs
    the same on both arms)."""
    rng = np.random.default_rng(seed)
    duration_us = n / (STEADY_RATE_RPS / 1e6)
    times = poisson_times(rng, STEADY_RATE_RPS, duration_us)[:n]
    return [(float(t),
             json.dumps({"session": f"s{j}", "prompt": [1] * PROMPT,
                         "n": DECODE}).encode())
            for j, t in enumerate(times)]


def _steady_point(n: int) -> dict:
    cm = _cost_model()
    trace = _steady_trace(n)

    plane = InferencePlane.build(cm, SLOSpec(deadline_us=DEADLINE_US),
                                 admission=True, cfg=_serving_cfg())
    plane.run_trace(trace)
    rep = plane.slo_report()
    rep_lats = sorted(lat for _, lat, ok in plane.outcomes if ok)

    sim, server, client = build_unreplicated(
        lambda: TokenServerApp(greedy_decode_fn(), cost_model=cm))
    for t, payload in trace:
        sim.at(t, (lambda p=payload: client.request(p)),
               note="unrepl.arrival")
    sim.run_until(lambda: len(client.latencies) >= len(trace),
                  timeout=60_000_000.0)
    unrepl = percentiles(client.latencies)

    row = {
        "n": len(trace),
        "rate_rps": STEADY_RATE_RPS,
        "replicated": dict(percentiles(rep_lats), shed=rep["shed"]),
        "unreplicated": unrepl,
        "overhead_p50_x": (percentiles(rep_lats)["p50"] /
                          max(unrepl["p50"], 1e-9)),
    }
    return row


def _flash_trace():
    sess = flash_crowd_times(np.random.default_rng(FLASH_SEED),
                             base_rps=FLASH_BASE_RPS,
                             peak_rps=FLASH_PEAK_RPS,
                             t_start_us=FLASH_T_START_US,
                             ramp_us=FLASH_RAMP_US, hold_us=FLASH_HOLD_US,
                             decay_us=FLASH_DECAY_US,
                             duration_us=FLASH_DURATION_US)
    return llm_session_trace(FLASH_SEED, FLASH_DURATION_US,
                             session_times=sess, mean_turns=2.0,
                             think_us=1_000.0, first_prompt_tokens=PROMPT,
                             next_prompt_tokens=4, decode_tokens=DECODE)


def _flash_point() -> dict:
    cm = _cost_model()
    trace = _flash_trace()
    slo = SLOSpec(deadline_us=DEADLINE_US)

    adm_plane = InferencePlane.build(cm, slo, admission=True,
                                     cfg=_serving_cfg())
    adm_plane.run_trace(trace, drain_us=10_000_000.0)
    adm = adm_plane.slo_report()

    # the no-admission arm must not dodge the collapse through a view
    # change: give it a patient progress timer and let the queue build
    off_plane = InferencePlane.build(cm, slo, admission=False,
                                     cfg=_serving_cfg(
                                         view_timeout_us=5_000_000.0))
    off_plane.run_trace(trace, drain_us=60_000_000.0)
    off = off_plane.slo_report()

    return {"n": len(trace), "admission": adm, "no_admission": off}


def run(smoke: bool = False) -> dict:
    tune_runtime()
    cm = _cost_model()
    out: dict = {
        "cost_model": {
            "name": cm.name,
            "decode_us_per_token": cm.decode_us_per_token(),
            "request_us": cm.request_us(PROMPT, DECODE),
            "capacity_rps": 1e6 / cm.request_us(PROMPT, DECODE),
        },
        "deadline_us": DEADLINE_US,
    }
    emit("inference.cost.us_per_token", cm.decode_us_per_token())

    steady = _steady_point(SMOKE_STEADY_N if smoke else STEADY_N)
    out["steady"] = steady
    emit("inference.steady.replicated_p50_us", steady["replicated"]["p50"],
         f"unrepl={steady['unreplicated']['p50']:.1f}us_"
         f"overhead={steady['overhead_p50_x']:.2f}x")
    assert steady["overhead_p50_x"] <= 2.0, (
        f"replication overhead {steady['overhead_p50_x']:.2f}x at p50 "
        f"blows the 2x bound over the unreplicated baseline")

    flash = _flash_point()
    out["flash"] = flash
    adm, off = flash["admission"], flash["no_admission"]
    emit("inference.flash.admission_served_p99_us", adm["served_p99_us"],
         f"shed={adm['shed']}/{adm['issued']}_"
         f"attain={adm['attainment']:.2f}")
    emit("inference.flash.no_admission_p99_us", off["served_p99_us"],
         f"attain={off['attainment']:.2f}")
    assert adm["served_p99_us"] <= DEADLINE_US, (
        f"admission failed its own SLO: served p99 "
        f"{adm['served_p99_us']:.0f}us > {DEADLINE_US:.0f}us deadline")
    assert off["served_p99_us"] >= 2.0 * DEADLINE_US, (
        "the no-admission arm did not collapse — the flash crowd is not "
        f"overloading the decode engine (p99 {off['served_p99_us']:.0f}us)")
    assert adm["attainment"] >= off["attainment"], (
        "shedding lost more SLO attainment than the queueing collapse")
    assert adm["shed"] > 0 and off["shed"] == 0
    return out


if __name__ == "__main__":
    run(smoke="--smoke" in sys.argv)
    print("inference: steady overhead + flash-crowd admission checks passed")
