"""Event-engine wall-clock benchmark — the repo's perf trajectory anchor.

Measures *host* performance (events/sec, wall-clock), not simulated time:
this is the number the zero-re-encode wire layer and the slim event engine
exist to improve, and the number CI guards against regressions
(``--check`` compares against ``benchmarks/baseline_engine.json``).

Three tiers, cheapest to fullest:

* ``engine.timer_events_per_sec`` — pure event-loop floor: self-
  rescheduling timers, no protocol, no network.
* ``engine.message_events_per_sec`` — the per-message plumbing
  (Node.send → NetworkModel → deliver → dispatch) on the unreplicated
  RPC baseline.
* ``engine.ubft_events_per_sec`` — the full uBFT hot path (batched
  consensus, CTBcast, TBcast, wire cache) under closed-loop load.

Usage::

    PYTHONPATH=src:. python benchmarks/engine_perf.py [--json PATH] [--check]
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit, tune_runtime  # noqa: E402

BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                             "baseline_engine.json")
#: CI fails when events/sec drops more than this fraction below baseline.
REGRESSION_TOLERANCE = 0.30
#: Improvement ratchet: when a tier beats its baseline by more than this
#: fraction, --check emits a GitHub ``::warning::`` annotation suggesting
#: a baseline re-record, so the regression floor tracks real progress
#: instead of rotting at an old number.
IMPROVEMENT_MARGIN = 0.25


def bench_timer_engine(n_events: int = 200_000) -> dict:
    """Pure event-loop floor: chains of self-rescheduling timers."""
    from repro.sim.events import Simulator
    sim = Simulator(seed=0)
    state = {"left": n_events}

    def tick() -> None:
        state["left"] -= 1
        if state["left"] > 0:
            sim.after(1.0, tick)

    # 64 concurrent timer chains exercise the heap, not just the top slot
    for i in range(64):
        state["left"] -= 1
        sim.after(1.0 + i * 0.01, tick)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    return {"events": sim.events_processed, "wall_s": wall,
            "events_per_sec": sim.events_processed / wall}


def bench_message_path(window_us: float = 10_000.0) -> dict:
    """Per-message plumbing floor: unreplicated RPC closed loop."""
    from repro.apps.flip import FlipApp
    from repro.baselines.unreplicated import (UnreplicatedClient,
                                              build_unreplicated)
    sim, _server, client = build_unreplicated(FlipApp)
    clients = [client] + [
        UnreplicatedClient(sim, client.net, client.registry, f"c{i}", "s0")
        for i in range(1, 16)]
    payload = b"x" * 32
    done = {"n": 0}

    def refire(cl):
        def cb(_res, _lat):
            done["n"] += 1
            cl.request(payload, cb)
        return cb

    for cl in clients:
        cl.request(payload, refire(cl))
    t0 = time.perf_counter()
    sim.run(until=sim.now + window_us)
    wall = time.perf_counter() - t0
    return {"events": sim.events_processed, "wall_s": wall,
            "events_per_sec": sim.events_processed / wall,
            "requests": done["n"]}


def bench_ubft_path(window_us: float = 10_000.0) -> dict:
    """Full uBFT hot path: batched+pipelined consensus closed loop."""
    from repro.apps.flip import FlipApp
    from repro.core import crypto
    from repro.core.consensus import ConsensusConfig
    from repro.core.smr import build_cluster
    cfg = ConsensusConfig(max_batch=8, pipeline_depth=4)
    cluster = build_cluster(FlipApp, cfg=cfg)
    crypto.reset_digest_stats()
    clients = [cluster.new_client() for _ in range(16)]
    payload = b"x" * 32
    done = {"n": 0}

    def refire(cl):
        def cb(_res, _lat):
            done["n"] += 1
            cl.request(payload, cb)
        return cb

    for cl in clients:
        cl.request(payload, refire(cl))
    t0 = time.perf_counter()
    cluster.sim.run(until=cluster.sim.now + window_us)
    wall = time.perf_counter() - t0
    # the engine counters prove the batched digest / fan-out paths are
    # actually taken on the hot path (gated by check_regression)
    engine = cluster.stats()["engine"]
    return {"events": cluster.sim.events_processed, "wall_s": wall,
            "events_per_sec": cluster.sim.events_processed / wall,
            "requests": done["n"], "engine": engine}


def run() -> dict:
    tune_runtime()
    out = {
        "timer": bench_timer_engine(),
        "message": bench_message_path(),
        "ubft": bench_ubft_path(),
    }
    for tier, r in out.items():
        emit(f"engine.{tier}_events_per_sec", r["events_per_sec"])
        emit(f"engine.{tier}_wall_s", r["wall_s"])
    return out


def check_regression(results: dict, baseline_path: str = BASELINE_PATH,
                     tolerance: float = REGRESSION_TOLERANCE) -> list:
    """Return a list of human-readable failures (empty = pass).

    Besides the regression floor, this gate:

    * warn-annotates (GitHub ``::warning::``) any tier that beats its
      baseline by more than ``IMPROVEMENT_MARGIN`` — the cue to re-record
      the baseline so the floor ratchets upward with real improvements;
    * fails if the uBFT tier ran with the batched digest / fan-out paths
      cold (counters zero) — the batch machinery silently falling back to
      scalar is a perf regression the events/s floor alone might hide.
    """
    if not os.path.exists(baseline_path):
        return [f"missing baseline {baseline_path}"]
    with open(baseline_path) as f:
        baseline = json.load(f)
    tolerance = baseline.get("tolerance", tolerance)
    failures = []
    for tier, base in baseline.get("tiers", {}).items():
        got = results.get(tier, {}).get("events_per_sec")
        floor = base["events_per_sec"] * (1.0 - tolerance)
        if got is None:
            failures.append(f"{tier}: no result")
        elif got < floor:
            failures.append(
                f"{tier}: {got:,.0f} events/s < floor {floor:,.0f} "
                f"(baseline {base['events_per_sec']:,.0f} - {tolerance:.0%})")
        elif got > base["events_per_sec"] * (1.0 + IMPROVEMENT_MARGIN):
            print(f"::warning title=engine perf improved::{tier}: "
                  f"{got:,.0f} events/s > baseline "
                  f"{base['events_per_sec']:,.0f} +{IMPROVEMENT_MARGIN:.0%} "
                  f"— re-record with engine_perf.py --record-baseline")
    engine = results.get("ubft", {}).get("engine")
    if engine is not None:
        digests = engine.get("digests", {})
        net = engine.get("net", {})
        if not digests.get("batch_fingerprint_items"):
            failures.append("ubft: batched fingerprint path never taken "
                            "(batch_fingerprint_items == 0)")
        if not net.get("fanout_msgs"):
            failures.append("ubft: batched fan-out path never taken "
                            "(fanout_msgs == 0)")
    return failures


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write results to PATH (BENCH_engine.json)")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) on >%d%% events/sec regression "
                         "vs the committed baseline"
                         % int(REGRESSION_TOLERANCE * 100))
    ap.add_argument("--record-baseline", action="store_true",
                    help="overwrite benchmarks/baseline_engine.json")
    ap.add_argument("--check-json", metavar="PATH", default=None,
                    help="like --check, but gate on an existing "
                         "BENCH_engine.json instead of re-running")
    args = ap.parse_args()
    if args.check_json:
        with open(args.check_json) as f:
            results = json.load(f)
        failures = check_regression(results)
        if failures:
            for msg in failures:
                print(f"REGRESSION: {msg}", file=sys.stderr)
            sys.exit(1)
        print("# perf check passed")
        return
    results = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")
    if args.record_baseline:
        payload = {"tiers": {t: {"events_per_sec": r["events_per_sec"]}
                             for t, r in results.items()},
                   "tolerance": REGRESSION_TOLERANCE}
        with open(BASELINE_PATH, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {BASELINE_PATH}")
    if args.check:
        failures = check_regression(results)
        if failures:
            for msg in failures:
                print(f"REGRESSION: {msg}", file=sys.stderr)
            sys.exit(1)
        print("# perf check passed")


if __name__ == "__main__":
    main()
