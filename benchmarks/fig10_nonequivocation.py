"""Figure 10: median latency of non-equivocation mechanisms vs message size
(one sender, two receivers): CTBcast fast path, CTBcast slow path, SGX
trusted counter.

Paper targets: CTBcast fast 2.2–11 µs; SGX ≈ 16 µs minimum; CTBcast slow
≈ 86 µs; fast path up to 6.5× faster than SGX.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.baselines.sgx_counter import build_ctbcast, build_sgx_broadcast

SIZES = (32, 256, 1024, 4096, 8192)
N = 100


def _ctb_lat(fast: bool, size: int) -> float:
    sim, nodes, deliv = build_ctbcast(fast=fast)
    bc = nodes[0]
    lats = []
    for k in range(N):
        t0 = sim.now
        bc.ctb.broadcast(k, b"m" * size)
        ok = sim.run_until(lambda: len(deliv.get(k, {})) >= 3,
                           timeout=1_000_000)
        assert ok, f"ctbcast({fast=}) stalled at k={k}"
        lats.append(max(deliv[k].values()) - t0)
    return float(np.median(lats))


def _sgx_lat(size: int) -> float:
    sim, sender, delivered = build_sgx_broadcast()
    lats = []
    for k in range(1, N + 1):
        t0 = sim.now
        sender.broadcast(b"m" * size)
        ok = sim.run_until(lambda: len(delivered.get(k, [])) >= 2,
                           timeout=1_000_000)
        assert ok
        lats.append(max(delivered[k]) - t0)
    return float(np.median(lats))


def run() -> dict:
    out = {}
    for size in SIZES:
        fast = _ctb_lat(True, size)
        sgx = _sgx_lat(size)
        out[size] = {"ctb_fast": fast, "sgx": sgx}
        emit(f"fig10.{size}B.ctb_fast", fast,
             f"vs_sgx={sgx / fast:.2f}x_faster")
        emit(f"fig10.{size}B.sgx_counter", sgx)
    slow = _ctb_lat(False, 32)
    out["slow32"] = slow
    emit("fig10.32B.ctb_slow", slow, "paper~86us")
    return out


if __name__ == "__main__":
    run()
