"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline benchmark reads
the dry-run artifacts (artifacts/dryrun/*.json) when present.

``--json`` additionally writes the repo's perf-trajectory artifacts:

* ``BENCH_engine.json``  — host performance (events/sec, wall-clock per
  tier) from ``benchmarks/engine_perf.py``;
* ``BENCH_protocol.json`` — simulated protocol results (p50/p99 µs,
  throughput kops per sweep point) from ``benchmarks/throughput.py``;
* ``BENCH_shared.json`` — multi-application substrate sharing (per-app
  latency + per-app per-pool memory) from ``benchmarks/shared_pools.py``
  (when the ``shared`` figure is run);
* ``BENCH_membership.json`` — reconfiguration-under-load tails (replica
  replacement × pool sync) from ``benchmarks/fig11_reconfig.py`` (when
  the ``membership`` figure is run);
* ``BENCH_sharded.json`` — sharded-service scale-out (K×load×Zipf sweep:
  uniform scaling curve, hot-shard p99 knee, cross-shard 2PC latency)
  from ``benchmarks/sharded.py`` (when the ``sharded`` figure is run);
* ``BENCH_selfheal.json`` — self-healing membership (gray-failure
  detect→replace timeline, rolling full-group rotation tails vs a
  no-fault baseline) from ``benchmarks/selfheal.py`` (when the
  ``selfheal`` figure is run);
* ``BENCH_inference.json`` — replicated inference serving (steady-state
  consensus overhead vs the unreplicated baseline, flash-crowd SLO
  attainment with vs without admission control) from
  ``benchmarks/inference.py`` (when the ``inference`` figure is run).

Usage:  PYTHONPATH=src python -m benchmarks.run [--json] [figure ...]
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _write_json(path: str, payload: dict) -> None:
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {path}", flush=True)


def main() -> None:
    from benchmarks import (engine_perf, fig7_app_latency, fig8_request_size,
                            fig9_breakdown, fig10_nonequivocation,
                            fig11_reconfig, fig11_tail_latency, inference,
                            selfheal, sharded, shared_pools, table2_memory,
                            throughput, roofline)
    mods = {
        "fig7": fig7_app_latency,
        "fig8": fig8_request_size,
        "fig9": fig9_breakdown,
        "fig10": fig10_nonequivocation,
        "fig11": fig11_tail_latency,
        "membership": fig11_reconfig,
        "table2": table2_memory,
        "throughput": throughput,
        "shared": shared_pools,
        "sharded": sharded,
        "selfheal": selfheal,
        "inference": inference,
        "engine": engine_perf,
        "roofline": roofline,
    }
    args = sys.argv[1:]
    want_json = "--json" in args
    explicit = [a for a in args if a != "--json"]
    wanted = explicit or list(mods)
    results: dict = {}
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        try:
            results[name] = mods[name].run()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # keep going — report the failure as a row
            import traceback
            traceback.print_exc()
            print(f"{name}.FAILED,0,{type(e).__name__}:{str(e)[:120]}")

    if want_json:
        # a module that already failed above must not crash the JSON pass;
        # with an explicit figure list, only the requested artifacts are
        # (re)computed — `--json shared` must not drag in the full sweeps
        backfill = () if explicit else ("engine", "throughput")
        for name in backfill:
            if name not in results:
                try:
                    results[name] = mods[name].run()
                except Exception:
                    import traceback
                    traceback.print_exc()
                    print(f"# {name} failed — skipping its JSON artifact")
        if "engine" in results:
            _write_json("BENCH_engine.json", results["engine"])
        if "shared" in results:
            shared = {str(k): v for k, v in results["shared"].items()}
            _write_json("BENCH_shared.json", shared)
        if "membership" in results:
            _write_json("BENCH_membership.json", results["membership"])
        if "sharded" in results:
            _write_json("BENCH_sharded.json", results["sharded"])
        if "selfheal" in results:
            _write_json("BENCH_selfheal.json", results["selfheal"])
        if "inference" in results:
            _write_json("BENCH_inference.json", results["inference"])
        if "throughput" in results:
            tp = results["throughput"]
            protocol = {
                label: {k: v for k, v in metrics.items()}
                for label, metrics in tp.items()
                if isinstance(metrics, dict)
            }
            protocol["speedup_b8_p4"] = tp.get("speedup_b8_p4")
            _write_json("BENCH_protocol.json", protocol)


if __name__ == "__main__":
    main()
