"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  The roofline benchmark reads
the dry-run artifacts (artifacts/dryrun/*.json) when present.

Usage:  PYTHONPATH=src python -m benchmarks.run [figure ...]
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (fig7_app_latency, fig8_request_size,
                            fig9_breakdown, fig10_nonequivocation,
                            fig11_tail_latency, table2_memory, throughput,
                            roofline)
    mods = {
        "fig7": fig7_app_latency,
        "fig8": fig8_request_size,
        "fig9": fig9_breakdown,
        "fig10": fig10_nonequivocation,
        "fig11": fig11_tail_latency,
        "table2": table2_memory,
        "throughput": throughput,
        "roofline": roofline,
    }
    wanted = sys.argv[1:] or list(mods)
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        try:
            mods[name].run()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:  # keep going — report the failure as a row
            import traceback
            traceback.print_exc()
            print(f"{name}.FAILED,0,{type(e).__name__}:{str(e)[:120]}")


if __name__ == "__main__":
    main()
