"""Figure 8: median end-to-end no-op latency vs request size for
Unreplicated / Mu / uBFT-fast / uBFT-slow / MinBFT (vanilla + HMAC).

Paper targets: unrepl 2.2→20 µs (32 B→8 KiB); Mu +64%/+26%; uBFT fast
≤ Mu+175%; MinBFT vanilla ≥ 566 µs; uBFT slow faster than vanilla MinBFT.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.apps.flip import FlipApp
from repro.baselines.minbft import build_minbft
from repro.baselines.mu import build_mu
from repro.baselines.unreplicated import build_unreplicated, run_closed_loop
from repro.core.consensus import ConsensusConfig
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario

SIZES = (32, 256, 1024, 4096, 8192)
N = 150


def median(lats):
    return float(np.median(np.asarray(lats)))


def run() -> dict:
    out = {}
    for size in SIZES:
        payload = b"x" * size
        row = {}

        sim, srv, client = build_unreplicated(FlipApp)
        row["unrepl"] = median(run_closed_loop(sim, client, payload, N))

        sim, client = build_mu(FlipApp)
        row["mu"] = median(run_closed_loop(sim, client, payload, N))

        res = run_scenario(ScenarioSpec(apps=[AppSpec(
            name="", app=FlipApp,
            workload=Workload(kind="closed", n_requests=N,
                              payload=payload))]))
        row["ubft_fast"] = median(res.latencies())

        cfg = ConsensusConfig(slow_mode="always", fast_enabled=False,
                              ctb_fast_enabled=False)
        res = run_scenario(ScenarioSpec(apps=[AppSpec(
            name="", app=FlipApp, cfg=cfg,
            workload=Workload(kind="closed", n_requests=60,
                              payload=payload))]))
        row["ubft_slow"] = median(res.latencies())

        for mode in ("vanilla", "hmac"):
            sim, client = build_minbft(FlipApp, client_mode=mode)
            row[f"minbft_{mode}"] = median(
                run_closed_loop(sim, client, payload, 60))

        out[size] = row
        for k, v in row.items():
            emit(f"fig8.{size}B.{k}", v)
        emit(f"fig8.{size}B.speedup_fast_vs_minbft",
             row["minbft_vanilla"] / row["ubft_fast"],
             f"paper_claims>=50x_at_small")
    return out


if __name__ == "__main__":
    run()
