"""Fig 11-style reconfiguration-under-load sweep: CTBcast slow-path tail
latency while *replica replacement* and a *pool sync* are in flight.

The paper's Fig 11 shows tail latency vs the CTBcast tail parameter; this
sweep extends the reconfiguration story to the membership-epoch machinery
(ISSUE 5): an open-loop kvstore app runs the registers-heavy slow path
while the fault schedule drives

* ``baseline``        — no faults (the reference tail);
* ``pool_sync``       — a memory-node crash + pool reconfiguration
                        (PR 2's pull/push state transfer);
* ``replace``         — a replica crash + ``replace_replica`` (non-voting
                        install, xfer via the pools, agreed epoch bump);
* ``replace+sync``    — both at once: the epoch bump commits while the
                        pool it is transferring state over is itself
                        mid-reconfiguration.

Per mode: p50/p99/p99.9 completion latency, stalled arrivals, peak
per-pool disaggregated memory (must stay < 1 MiB throughout — sampled,
not just at the end).  ``benchmarks/run.py --json membership`` writes the
result as ``BENCH_membership.json``.

Usage:  PYTHONPATH=src:. python benchmarks/fig11_reconfig.py [--smoke]
"""

from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, tune_runtime
from repro.apps.kvstore import KVStoreApp, set_req
from repro.core.consensus import ConsensusConfig
from repro.core.registers import POOL_MEMORY_BUDGET
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario
from repro.sim.faults import FaultSchedule

MODES = ("baseline", "pool_sync", "replace", "replace+sync")


def _cfg() -> ConsensusConfig:
    return ConsensusConfig(t=32, window=32, slow_mode="always",
                           ctb_fast_enabled=False,
                           view_timeout_us=20_000.0)


def _schedule(mode: str, substrate) -> FaultSchedule:
    sched = FaultSchedule()
    if mode in ("pool_sync", "replace+sync"):
        sched.add(900.0, "crash", "m1")
        sched.add(1600.0, "reconfigure", ("pool0", "m1"))
    if mode in ("replace", "replace+sync"):
        sched.add(1100.0, "crash", "r2")
        sched.add(1800.0, "replace_replica", "r2")
    return sched


def _run_mode(mode: str, rate_rps: float, duration_us: float,
              seed: int) -> dict:
    peak = {"bytes": 0}

    def _faults(substrate, m=mode):
        # piggy-back a mid-run memory sampler on the faults hook (it gets
        # the live substrate before the workloads start): peak per-pool
        # bytes *throughout* the transfer, not just at the end
        def sample() -> None:
            peak["bytes"] = max(peak["bytes"],
                                max(p.memory_bytes()
                                    for p in substrate.pools))
        substrate.sim.periodic(100.0, sample)
        return _schedule(m, substrate)

    spec = ScenarioSpec(
        n_pools=2, seed=seed, drain_us=30_000.0, faults=_faults,
        apps=[AppSpec(
            name="", app=KVStoreApp, cfg=_cfg(),
            workload=Workload(kind="open", rate_rps=rate_rps,
                              duration_us=duration_us,
                              payload_fn=lambda i: set_req(
                                  b"k%d" % (i % 8), b"v%d" % i),
                              seed=seed + 1,
                              timeout_us=120_000_000.0))])
    res = run_scenario(spec)
    # sample once more after drain (the pools retain transferred state)
    peak["bytes"] = max(peak["bytes"],
                        max(p.memory_bytes() for p in res.substrate.pools))
    lats = np.asarray(res.latencies())
    app = res.apps[""]
    cluster = res.clusters[""]
    live = [r for r in cluster.replicas if not r.crashed]
    row = {f"p{p}": float(np.percentile(lats, p)) if len(lats) else 0.0
           for p in (50, 99, 99.9)}
    row.update({
        "n": int(len(lats)),
        "issued": app.issued,
        "stalled": app.stalled,
        "epoch": max(r.membership.epoch for r in live),
        "replacements": len(cluster.replacements),
        "pool_syncs": sum(len(p.reconfigurations)
                          for p in res.substrate.pools),
        "peak_pool_bytes": int(peak["bytes"]),
    })
    assert row["peak_pool_bytes"] < POOL_MEMORY_BUDGET, \
        f"{mode}: pool exceeded the Table 2 budget"
    if mode in ("replace", "replace+sync"):
        assert row["epoch"] == 1, f"{mode}: epoch bump never committed"
        assert all(not r.joining for r in live), \
            f"{mode}: joiner never activated"
    if mode in ("pool_sync", "replace+sync"):
        assert row["pool_syncs"] >= 1, f"{mode}: pool sync never ran"
    return row


def run(smoke: bool = False) -> dict:
    tune_runtime()
    rate = 4_000.0 if smoke else 8_000.0
    duration = 4_000.0 if smoke else 12_000.0
    out: dict = {}
    for mode in MODES:
        row = _run_mode(mode, rate_rps=rate, duration_us=duration, seed=11)
        out[mode] = row
        emit(f"fig11_reconfig.{mode}.p50", row["p50"])
        emit(f"fig11_reconfig.{mode}.p99", row["p99"],
             f"p99.9={row['p99.9']:.1f};stalled={row['stalled']};"
             f"peak_pool_KiB={row['peak_pool_bytes'] / 1024:.0f}")
    base = out["baseline"]["p99"]
    if base > 0:
        for mode in MODES[1:]:
            out[mode]["p99_vs_baseline"] = out[mode]["p99"] / base
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)
