"""Shared benchmark helpers: closed- and open-loop drivers + percentiles.

The closed-loop driver re-fires on completion (self-throttling: offered
load tracks service rate).  The open-loop driver injects a seeded Poisson
arrival process at a fixed rate regardless of completions — the right
workload for interference sweeps (``benchmarks/shared_pools.py``), where a
slowdown must show up as queueing/latency rather than silently reducing
the offered load.  Both are selectable per app from a
``repro.scenario.Workload`` (kind="closed" / "open").
"""

from __future__ import annotations

import gc
from typing import Callable, List, Optional

import numpy as np

_TUNED = False


def tune_runtime() -> None:
    """Benchmark-process runtime tuning: raise the gen-0 GC threshold so
    collection sweeps don't interleave with the event loop (the simulator
    allocates millions of short-lived closures/tuples that plain refcounting
    already reclaims; cyclic garbage is rare and still collected, just in
    bigger batches).  Affects wall-clock only — simulated results are
    independent of the collector."""
    global _TUNED
    if not _TUNED:
        gc.set_threshold(500_000, 50, 50)
        _TUNED = True


def percentiles(lats: List[float], ps=(50, 90, 95, 99)) -> dict:
    # single pass: np.percentile does its own (partial) sorting internally —
    # a python-level pre-sort was pure overhead
    arr = np.asarray(lats)
    return {f"p{p}": float(np.percentile(arr, p)) for p in ps}


def closed_loop_cluster(cluster, client, payload_fn, n: int,
                        timeout: float = 30_000_000.0) -> List[float]:
    """Issue n requests back-to-back on a uBFT cluster; return the
    latencies of *this run only* (a client reused across sweep points keeps
    its lifetime ``latencies`` list — slicing from this run's start index
    prevents double-counting)."""
    start = len(client.latencies)
    state = {"left": n}

    def fire(*_):
        state["left"] -= 1
        if state["left"] > 0:
            client.request(payload_fn(n - state["left"]), fire)

    client.request(payload_fn(0), fire)
    ok = cluster.sim.run_until(lambda: state["left"] <= 0, timeout=timeout)
    if not ok:
        raise TimeoutError(f"closed loop stalled with {state['left']} left")
    return list(client.latencies[start:])


def open_loop_cluster(cluster, payload_fn, rate_rps: float,
                      duration_us: float, n_clients: int = 1, seed: int = 0,
                      timeout: float = 60_000_000.0) -> List[float]:
    """Open-loop (Poisson-arrival, seeded) counterpart of
    :func:`closed_loop_cluster`: inject arrivals at ``rate_rps`` per client
    over ``duration_us``, drain, return completion latencies."""
    from repro.scenario import open_loop
    return open_loop(cluster, payload_fn, rate_rps, duration_us,
                     n_clients=n_clients, seed=seed, timeout_us=timeout)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
