"""Shared benchmark helpers: closed-loop drivers + percentile extraction."""

from __future__ import annotations

import sys
from typing import Callable, List, Optional

import numpy as np


def percentiles(lats: List[float], ps=(50, 90, 95, 99)) -> dict:
    arr = np.asarray(sorted(lats))
    return {f"p{p}": float(np.percentile(arr, p)) for p in ps}


def closed_loop_cluster(cluster, client, payload_fn, n: int,
                        timeout: float = 30_000_000.0) -> List[float]:
    """Issue n requests back-to-back on a uBFT cluster; return latencies."""
    state = {"left": n}

    def fire(*_):
        state["left"] -= 1
        if state["left"] > 0:
            client.request(payload_fn(n - state["left"]), fire)

    client.request(payload_fn(0), fire)
    ok = cluster.sim.run_until(lambda: state["left"] <= 0, timeout=timeout)
    if not ok:
        raise TimeoutError(f"closed loop stalled with {state['left']} left")
    return list(client.latencies)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
