"""Self-healing membership benchmark (ISSUE 8): autonomous gray-failure
recovery and rolling full-group rotation under open-loop load.

Two modes:

* ``detect``   — a seeded gray failure (``slow_replica``: the replica
                 stays up but delays and drops its sends) hits mid-run;
                 the suspicion layer must detect it, execute the
                 precomputed plan and return the group to the fast path
                 autonomously.  Reported: the detection → fire → active
                 timeline relative to the fault, plus tail latency.
* ``rotation`` — a rolling 2f+1 full-group rotation (every seat replaced
                 through consecutive epoch bumps, strictly one at a time)
                 underneath the same open-loop workload, against a
                 no-fault baseline.  Gate: rotation p99 ≤ 2.5× baseline
                 p99 (cf. the single-replacement 1.78× in
                 BENCH_membership.json) and all 2f+1 seats replaced.

``benchmarks/run.py --json selfheal`` writes ``BENCH_selfheal.json``.

Usage:  PYTHONPATH=src:. python benchmarks/selfheal.py [--smoke]
"""

from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, tune_runtime
from repro.apps.kvstore import KVStoreApp, set_req
from repro.core.consensus import ConsensusConfig
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario
from repro.sim.faults import FaultSchedule

ROTATION_P99_BOUND = 2.5   # × the no-fault baseline p99

FAULT_AT_US = 2_000.0


def _cfg() -> ConsensusConfig:
    return ConsensusConfig(t=32, window=32, slow_mode="always",
                           ctb_fast_enabled=False,
                           view_timeout_us=20_000.0)


def _spec(seed: int, rate_rps: float, duration_us: float, faults=None,
          drain_us: float = 60_000.0) -> ScenarioSpec:
    return ScenarioSpec(
        n_pools=2, seed=seed, drain_us=drain_us, faults=faults,
        apps=[AppSpec(
            name="", app=KVStoreApp, cfg=_cfg(), self_heal=True,
            workload=Workload(kind="open", rate_rps=rate_rps,
                              duration_us=duration_us,
                              payload_fn=lambda i: set_req(
                                  b"k%d" % (i % 8), b"v%d" % i),
                              seed=seed + 1,
                              timeout_us=120_000_000.0))])


def _row(res) -> dict:
    lats = np.asarray(res.latencies())
    row = {f"p{p}": float(np.percentile(lats, p)) if len(lats) else 0.0
           for p in (50, 99, 99.9)}
    row["n"] = int(len(lats))
    row["issued"] = res.apps[""].issued
    row["stalled"] = res.apps[""].stalled
    return row


def _run_detect(rate_rps: float, duration_us: float, seed: int) -> dict:
    def _faults(_substrate):
        return FaultSchedule().add(
            FAULT_AT_US, "slow_replica",
            ("r1", {"delay_us": 1500.0, "drop": 0.5, "seed": seed}))

    res = run_scenario(_spec(seed, rate_rps, duration_us, faults=_faults))
    cluster = res.clusters[""]
    mon = cluster.health_monitor
    assert mon.replacements, "gray failure went undetected"
    rec = mon.replacements[0]
    assert rec["target"] == "r1", rec
    assert rec["t_active"] is not None, "joiner never activated"
    assert "r1" not in cluster.current_members()
    row = _row(res)
    row.update({
        "fault_at": FAULT_AT_US,
        "detect_us": rec["t_detect"] - FAULT_AT_US,
        "fire_us": rec["t_fire"] - FAULT_AT_US,
        "recover_us": rec["t_active"] - FAULT_AT_US,
        "epoch": cluster.current_epoch(),
        "false_suspicions": sorted(
            t for t in cluster.stats().get("suspicions", {}) if t != "r1"),
    })
    assert row["false_suspicions"] == [], row["false_suspicions"]
    return row


def _run_rotation(rate_rps: float, duration_us: float, seed: int) -> dict:
    def _faults(substrate):
        cluster = substrate.clusters[""]

        def start() -> None:
            cluster.health_monitor.rotate()
        substrate.sim.at(FAULT_AT_US, start)
        return FaultSchedule()

    res = run_scenario(_spec(seed, rate_rps, duration_us, faults=_faults,
                             drain_us=150_000.0))
    cluster = res.clusters[""]
    mon = cluster.health_monitor
    n_seats = len(cluster.replicas)
    assert not mon.rotating, "rotation never completed"
    assert len(mon.rotation_log) == n_seats
    assert all(e["t_done"] is not None for e in mon.rotation_log)
    assert cluster.current_epoch() == n_seats
    row = _row(res)
    row.update({
        "epoch": cluster.current_epoch(),
        "seats_replaced": len(mon.rotation_log),
        "rotation_total_us": (mon.rotation_log[-1]["t_done"] -
                              mon.rotation_log[0]["t_fire"]),
        "step_us": [e["t_done"] - e["t_fire"] for e in mon.rotation_log],
    })
    return row


def run(smoke: bool = False) -> dict:
    tune_runtime()
    rate = 4_000.0 if smoke else 8_000.0
    duration = 6_000.0 if smoke else 12_000.0
    out: dict = {}

    base = _row(run_scenario(_spec(11, rate, duration)))
    out["baseline"] = base
    emit("selfheal.baseline.p99", base["p99"],
         f"p50={base['p50']:.1f};n={base['n']}")

    det = _run_detect(rate, duration, seed=11)
    out["detect"] = det
    emit("selfheal.detect.recover_us", det["recover_us"],
         f"detect={det['detect_us']:.0f};fire={det['fire_us']:.0f};"
         f"p99={det['p99']:.1f}")

    rot = _run_rotation(rate, duration, seed=11)
    out["rotation"] = rot
    if base["p99"] > 0:
        rot["p99_vs_baseline"] = rot["p99"] / base["p99"]
        assert rot["p99_vs_baseline"] <= ROTATION_P99_BOUND, (
            f"rotation tail cost {rot['p99_vs_baseline']:.2f}x exceeds "
            f"the {ROTATION_P99_BOUND}x bound")
    emit("selfheal.rotation.p99", rot["p99"],
         f"vs_baseline={rot.get('p99_vs_baseline', 0):.2f}x;"
         f"seats={rot['seats_replaced']};"
         f"total_us={rot['rotation_total_us']:.0f}")
    return out


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)
