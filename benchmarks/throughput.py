"""Throughput of the batched + pipelined consensus hot path (§9 "What
about uBFT's throughput?" — and beyond it).

The paper's evaluation is latency-centric: one client request per CTBcast
slot bounds throughput by the protocol round (~91 kops at 32 B).  This
benchmark drives a closed-loop multi-client load generator and sweeps the
leader's ``max_batch`` × ``pipeline_depth``: the leader coalesces pending
requests into one slot and keeps several slots in flight, so protocol cost
amortizes over the batch.  Reported per configuration: requests/s, p50/p99
latency, and wire bytes per request — against the seed's
one-request-per-slot configuration and the unreplicated / Mu / MinBFT
baselines at equal replica count.

Execution model: every sweep point is an independent, seeded simulation, so
the sweep fans out across worker processes (``--serial`` forces one
process).  Parallelism changes *wall-clock only* — each simulation is
deterministic in its own process and its results are bit-identical either
way (the golden-trace test enforces this for the engine itself).
"""

from __future__ import annotations

import multiprocessing as mp
import os
import sys

from benchmarks.common import emit, tune_runtime

WINDOW_US = 20_000.0
N_CLIENTS = 32
PAYLOAD = b"x" * 32

#: (label, max_batch, pipeline_depth); (1, 1) is the seed's configuration.
SWEEP = [
    ("b1_p1", 1, 1),
    ("b4_p4", 4, 4),
    ("b8_p4", 8, 4),
    ("b16_p8", 16, 8),
]


def _closed_loop(sim, clients, window_us: float):
    """Drive every client closed-loop for ``window_us``; return
    (completed, sorted latencies)."""
    done = {"n": 0}
    lats = []

    def refire(cl):
        def cb(_res, lat):
            done["n"] += 1
            lats.append(lat)
            cl.request(PAYLOAD, cb)
        return cb

    for cl in clients:
        cl.request(PAYLOAD, refire(cl))
    sim.run(until=sim.now + window_us)
    lats.sort()
    return done["n"], lats


def _pcts(lats):
    if not lats:
        return 0.0, 0.0
    return (lats[len(lats) // 2], lats[min(len(lats) - 1,
                                           int(len(lats) * 0.99))])


# ---------------------------------------------------------------- jobs
# One function per sweep point — module-level so they cross the process
# boundary; each builds its own seeded simulator (deterministic in
# isolation, so the fan-out cannot change any simulated number).

def _job_ubft(args):
    label, max_batch, depth = args
    tune_runtime()
    from repro.apps.flip import FlipApp
    from repro.core.consensus import ConsensusConfig
    from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario
    cfg = ConsensusConfig(max_batch=max_batch, pipeline_depth=depth)
    res = run_scenario(ScenarioSpec(apps=[AppSpec(
        name="", app=FlipApp, cfg=cfg,
        workload=Workload(kind="closed", duration_us=WINDOW_US,
                          n_clients=N_CLIENTS, payload=PAYLOAD))]))
    lats = sorted(res.latencies())
    n = len(lats)
    p50, p99 = _pcts(lats)
    return (label, {"kops": n / (WINDOW_US / 1e6) / 1e3,
                    "p50_us": p50, "p99_us": p99,
                    "bytes_per_req": res.bytes_sent / max(1, n),
                    "events": res.events_processed})


def _job_unreplicated(_):
    tune_runtime()
    from repro.apps.flip import FlipApp
    from repro.baselines.unreplicated import (UnreplicatedClient,
                                              build_unreplicated)
    sim, _server, client = build_unreplicated(FlipApp)
    clients = [client] + [
        UnreplicatedClient(sim, client.net, client.registry, f"c{i}", "s0")
        for i in range(1, N_CLIENTS)]
    n, _lats = _closed_loop(sim, clients, WINDOW_US)
    return ("unreplicated", {"kops": n / (WINDOW_US / 1e6) / 1e3,
                             "events": sim.events_processed})


def _job_mu(_):
    tune_runtime()
    from repro.apps.flip import FlipApp
    from repro.baselines.mu import build_mu
    sim, client = build_mu(FlipApp)
    n, _lats = _closed_loop(sim, [client], WINDOW_US)
    return ("mu", {"kops": n / (WINDOW_US / 1e6) / 1e3,
                   "events": sim.events_processed})


def _job_minbft(_):
    tune_runtime()
    from repro.apps.flip import FlipApp
    from repro.baselines.minbft import build_minbft
    sim, client = build_minbft(FlipApp)
    n, _lats = _closed_loop(sim, [client], WINDOW_US)
    return ("minbft", {"kops": n / (WINDOW_US / 1e6) / 1e3,
                       "events": sim.events_processed})


_JOBS = ([(_job_ubft, cfg) for cfg in SWEEP] +
         [(_job_unreplicated, None), (_job_mu, None), (_job_minbft, None)])


def _run_jobs(serial: bool = False):
    if serial or os.environ.get("UBFT_BENCH_SERIAL"):
        return [fn(arg) for fn, arg in _JOBS]
    workers = min(len(_JOBS), os.cpu_count() or 1)
    if workers <= 1:
        return [fn(arg) for fn, arg in _JOBS]
    ctx = mp.get_context("fork" if "fork" in mp.get_all_start_methods()
                         else "spawn")
    with ctx.Pool(workers) as pool:
        handles = [pool.apply_async(fn, (arg,)) for fn, arg in _JOBS]
        return [h.get() for h in handles]


def run(serial: bool = False) -> dict:
    tune_runtime()
    out = dict(_run_jobs(serial))

    for label, _b, _p in SWEEP:
        r = out[label]
        emit(f"throughput.ubft.{label}.kops", r["kops"],
             "paper~91kops_one_req_per_slot" if label == "b1_p1" else "")
        emit(f"throughput.ubft.{label}.p50_us", r["p50_us"])
        emit(f"throughput.ubft.{label}.p99_us", r["p99_us"])
        emit(f"throughput.ubft.{label}.bytes_per_req", r["bytes_per_req"])

    speedup = out["b8_p4"]["kops"] / max(1e-9, out["b1_p1"]["kops"])
    out["speedup_b8_p4"] = speedup
    emit("throughput.ubft.speedup_b8_p4_vs_seed", speedup,
         "acceptance>=5x")

    emit("throughput.unreplicated.kops", out["unreplicated"]["kops"])
    emit("throughput.mu.kops", out["mu"]["kops"], "single_client")
    emit("throughput.minbft.kops", out["minbft"]["kops"], "single_client")
    return out


if __name__ == "__main__":
    run(serial="--serial" in sys.argv)
