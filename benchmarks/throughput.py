"""§9 "What about uBFT's throughput?" — the paper: ≈91 kops for 32 B
requests as the inverse of latency, ≈2× that by interleaving two requests
in the slack of a consensus slot.

We measure closed-loop throughput with 1, 2, 4 and 8 concurrent clients
(uBFT's sliding window interleaves their slots naturally) over a 20 ms
simulated window.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.apps.flip import FlipApp
from repro.core.smr import build_cluster

WINDOW_US = 20_000.0


def run() -> dict:
    out = {}
    for n_clients in (1, 2, 4, 8):
        cluster = build_cluster(FlipApp)
        clients = [cluster.new_client() for _ in range(n_clients)]
        done = {"n": 0}

        def refire(cl):
            def cb(_res, _lat):
                done["n"] += 1
                cl.request(b"x" * 32, cb)
            return cb

        for cl in clients:
            cl.request(b"x" * 32, refire(cl))
        cluster.sim.run(until=WINDOW_US)
        kops = done["n"] / (WINDOW_US / 1e6) / 1e3
        out[n_clients] = kops
        emit(f"throughput.{n_clients}clients.kops", kops,
             "paper~91kops_at_1_187kops_interleaved" if n_clients <= 2 else "")
    return out


if __name__ == "__main__":
    run()
