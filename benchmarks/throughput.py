"""Throughput of the batched + pipelined consensus hot path (§9 "What
about uBFT's throughput?" — and beyond it).

The paper's evaluation is latency-centric: one client request per CTBcast
slot bounds throughput by the protocol round (~91 kops at 32 B).  This
benchmark drives a closed-loop multi-client load generator and sweeps the
leader's ``max_batch`` × ``pipeline_depth``: the leader coalesces pending
requests into one slot and keeps several slots in flight, so protocol cost
amortizes over the batch.  Reported per configuration: requests/s, p50/p99
latency, and wire bytes per request — against the seed's
one-request-per-slot configuration and the unreplicated / Mu / MinBFT
baselines at equal replica count.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.apps.flip import FlipApp
from repro.baselines.minbft import build_minbft
from repro.baselines.mu import build_mu
from repro.baselines.unreplicated import UnreplicatedClient, build_unreplicated
from repro.core.consensus import ConsensusConfig
from repro.core.smr import build_cluster

WINDOW_US = 20_000.0
N_CLIENTS = 32
PAYLOAD = b"x" * 32

#: (label, max_batch, pipeline_depth); (1, 1) is the seed's configuration.
SWEEP = [
    ("b1_p1", 1, 1),
    ("b4_p4", 4, 4),
    ("b8_p4", 8, 4),
    ("b16_p8", 16, 8),
]


def _closed_loop(sim, clients, window_us: float):
    """Drive every client closed-loop for ``window_us``; return
    (completed, sorted latencies)."""
    done = {"n": 0}
    lats = []

    def refire(cl):
        def cb(_res, lat):
            done["n"] += 1
            lats.append(lat)
            cl.request(PAYLOAD, cb)
        return cb

    for cl in clients:
        cl.request(PAYLOAD, refire(cl))
    sim.run(until=sim.now + window_us)
    lats.sort()
    return done["n"], lats


def _pcts(lats):
    if not lats:
        return 0.0, 0.0
    return (lats[len(lats) // 2], lats[min(len(lats) - 1,
                                           int(len(lats) * 0.99))])


def run() -> dict:
    out = {}

    # --- uBFT: batch × pipeline sweep ---------------------------------
    for label, max_batch, depth in SWEEP:
        cfg = ConsensusConfig(max_batch=max_batch, pipeline_depth=depth)
        cluster = build_cluster(FlipApp, cfg=cfg)
        clients = [cluster.new_client() for _ in range(N_CLIENTS)]
        n, lats = _closed_loop(cluster.sim, clients, WINDOW_US)
        kops = n / (WINDOW_US / 1e6) / 1e3
        p50, p99 = _pcts(lats)
        bytes_per_req = cluster.net.bytes_sent / max(1, n)
        out[label] = {"kops": kops, "p50_us": p50, "p99_us": p99,
                      "bytes_per_req": bytes_per_req}
        emit(f"throughput.ubft.{label}.kops", kops,
             "paper~91kops_one_req_per_slot" if label == "b1_p1" else "")
        emit(f"throughput.ubft.{label}.p50_us", p50)
        emit(f"throughput.ubft.{label}.p99_us", p99)
        emit(f"throughput.ubft.{label}.bytes_per_req", bytes_per_req)

    speedup = out["b8_p4"]["kops"] / max(1e-9, out["b1_p1"]["kops"])
    out["speedup_b8_p4"] = speedup
    emit("throughput.ubft.speedup_b8_p4_vs_seed", speedup,
         "acceptance>=5x")

    # --- baselines at the same closed-loop load -----------------------
    sim, _server, client = build_unreplicated(FlipApp)
    clients = [client] + [
        UnreplicatedClient(sim, client.net, client.registry, f"c{i}", "s0")
        for i in range(1, N_CLIENTS)]
    n, lats = _closed_loop(sim, clients, WINDOW_US)
    out["unreplicated"] = {"kops": n / (WINDOW_US / 1e6) / 1e3}
    emit("throughput.unreplicated.kops", out["unreplicated"]["kops"])

    sim, client = build_mu(FlipApp)
    n, lats = _closed_loop(sim, [client], WINDOW_US)
    out["mu"] = {"kops": n / (WINDOW_US / 1e6) / 1e3}
    emit("throughput.mu.kops", out["mu"]["kops"], "single_client")

    sim, client = build_minbft(FlipApp)
    n, lats = _closed_loop(sim, [client], WINDOW_US)
    out["minbft"] = {"kops": n / (WINDOW_US / 1e6) / 1e3}
    emit("throughput.minbft.kops", out["minbft"]["kops"], "single_client")

    return out


if __name__ == "__main__":
    run()
