"""Figure 7: end-to-end latency of Flip / KV stores / matching engine when
unreplicated, replicated via Mu, and replicated via uBFT's fast path.

Paper targets: uBFT ≈ Mu + 7.5 µs at p90; ~3× Mu for Flip, ~2× for
Liquibook, ~1.5× for the KV stores; extra variance < 3 µs.
"""

from __future__ import annotations

import struct

import numpy as np

from benchmarks.common import emit, percentiles
from repro.apps.flip import FlipApp
from repro.apps.kvstore import KVStoreApp, get_req, set_req
from repro.apps.matching import MatchingEngineApp, order_req
from repro.baselines.mu import build_mu
from repro.baselines.unreplicated import build_unreplicated, run_closed_loop
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario

N = 300


def _payload_fn(app_name: str):
    rng = np.random.default_rng(0)

    def kv(i):
        # paper workload: 16 B keys, 32 B values, 30% GET
        key = b"k%014d" % (i % 64)
        if rng.random() < 0.3:
            return get_req(key)
        return set_req(key, b"v" + b"x" * 31)

    def flip(i):
        return b"f" * 32

    def liqui(i):
        side = "buy" if i % 2 == 0 else "sell"
        price = 100 + (i * 7) % 11 - 5
        return order_req(side, i, price, 10)

    return {"flip": flip, "memcached-kv": kv, "redis-kv": kv,
            "liquibook": liqui}[app_name]


APPS = {
    "flip": FlipApp,
    "memcached-kv": KVStoreApp,
    "redis-kv": KVStoreApp,
    "liquibook": MatchingEngineApp,
}


def run() -> dict:
    out = {}
    for name, app_cls in APPS.items():
        pf = _payload_fn(name)

        sim, srv, client = build_unreplicated(app_cls)
        lats = run_closed_loop(sim, client, pf(0), N)
        unrepl = percentiles(lats)

        sim, client = build_mu(app_cls)
        lats = run_closed_loop(sim, client, pf(0), N)
        mu = percentiles(lats)

        res = run_scenario(ScenarioSpec(apps=[AppSpec(
            name="", app=app_cls,
            workload=Workload(kind="closed", n_requests=N, payload_fn=pf))]))
        ubft = percentiles(res.latencies())

        out[name] = {"unrepl": unrepl, "mu": mu, "ubft": ubft}
        emit(f"fig7.{name}.unrepl.p90", unrepl["p90"])
        emit(f"fig7.{name}.mu.p90", mu["p90"])
        emit(f"fig7.{name}.ubft.p90", ubft["p90"],
             f"overhead_vs_mu={ubft['p90'] - mu['p90']:.1f}us;"
             f"ratio={ubft['p90'] / mu['p90']:.2f}x;"
             f"variance={ubft['p95'] - ubft['p50']:.1f}us")
    return out


if __name__ == "__main__":
    run()
