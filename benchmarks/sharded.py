"""Sharded-service scale-out: K uBFT groups over one shared substrate.

One 2f+1 group saturates around 1 Mops (BENCH_protocol.json b8_p4), so the
service plane scales *out*: ``repro.service.ShardedService`` hash-partitions
the keyspace across K groups on ONE substrate.  Three sweeps:

* **scaling** — uniform keys, fixed per-shard ConsensusConfig, closed-loop
  load proportional to K: aggregate throughput must scale ≥3× from K=1 to
  K=4 (each shard is an independent consensus instance; the shared
  substrate adds only event-loop interleaving, not ordering coupling).
* **zipf knee** — K=4 fixed, open-loop at a fixed aggregate rate the
  uniform spread handles comfortably; sweeping Zipf θ concentrates the
  keyspace onto a hot shard until it saturates — the p99 "knee" is the
  cost of skew that partitioning alone cannot shed (split/merge, the
  remaining ROADMAP work, is the answer; this sweep is its baseline).
* **cross_shard** — 2PC MSETs spanning two shards: commit latency vs the
  single-shard MSET fast path, plus the abort rate under key contention.
* **split** — the knee's answer (ISSUE 7): the same Zipf skew under an
  open-loop rate that *ramps* (a rush), and mid-run the hot shard is
  *split* into a freshly attached group while it is still healthy.  Two
  byte-identical arrival schedules, static K vs live split: the static
  hot shard is carried past its saturation cliff by the ramp; the split
  run sheds the range first.  The hot-shard population's late-window
  p99 must improve ≥3×.

Usage:  PYTHONPATH=src:. python benchmarks/sharded.py [--smoke]
"""

from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, percentiles, tune_runtime
from repro.core.consensus import ConsensusConfig
from repro.scenario import ScenarioSpec, ServiceSpec, Workload, run_scenario
from repro.workloads import ramp_times

N_POOLS = 2
KEYSPACE = 128
SCALE_SWEEP = (1, 2, 4)
SMOKE_SCALE_SWEEP = (1, 4)
THETAS = (0.0, 0.8, 1.2)
SMOKE_THETAS = (0.0, 1.2)
KNEE_K = 4
DURATION_US = 4_000.0
CLIENTS_PER_SHARD = 8
ZIPF_RATE_RPS = 1_200_000.0    # aggregate; ~comfortable for 4 uniform shards

SPLIT_THETA = 1.2
SPLIT_DURATION_US = 8_000.0
SPLIT_AT_US = 1_500.0          # act while the hot shard is still healthy
SPLIT_LATE_US = 5_500.0        # tail measured once the rush has arrived
#: the offered load ramps linearly (a "rush"): the static hot shard is
#: pushed past its saturation cliff mid-run, the split run sheds the
#: range before the rush peaks
SPLIT_RATE0_RPS = 800_000.0
SPLIT_RATE1_RPS = 1_400_000.0
SMOKE_SPLIT_DURATION_US = 5_000.0
SMOKE_SPLIT_AT_US = 1_000.0
SMOKE_SPLIT_LATE_US = 3_500.0


def _cfg() -> ConsensusConfig:
    # the *fixed per-shard config* of the scaling axis: batched+pipelined
    # fast path, small window so checkpoints exercise the shared pools
    return ConsensusConfig(t=16, window=32, max_batch=8, pipeline_depth=8,
                           view_timeout_us=40_000.0)


def _set_op(i: int, key: bytes):
    return ("set", key, b"v%d" % i)


def _scale_point(k: int) -> dict:
    spec = ScenarioSpec(
        apps=[], n_pools=N_POOLS, seed=0,
        services=[ServiceSpec(
            name="kv", n_shards=k, cfg=_cfg(),
            workload=Workload(kind="closed", duration_us=DURATION_US,
                              n_clients=CLIENTS_PER_SHARD * k,
                              keyspace=KEYSPACE, zipf_theta=0.0, key_seed=7,
                              payload_fn=_set_op))])
    res = run_scenario(spec)
    ar = res.apps["kv"]
    pcts = percentiles(ar.latencies)
    return {"n_shards": k, "completed": ar.completed,
            "tput_kops": ar.completed / DURATION_US * 1e3,
            "p50_us": pcts["p50"], "p99_us": pcts["p99"],
            "events": res.events_processed}


def _zipf_point(theta: float) -> dict:
    spec = ScenarioSpec(
        apps=[], n_pools=N_POOLS, seed=0,
        services=[ServiceSpec(
            name="kv", n_shards=KNEE_K, cfg=_cfg(),
            workload=Workload(kind="open",
                              rate_rps=ZIPF_RATE_RPS / KNEE_K,
                              duration_us=DURATION_US, n_clients=KNEE_K,
                              keyspace=KEYSPACE, zipf_theta=theta,
                              key_seed=7, seed=40,
                              payload_fn=_set_op,
                              timeout_us=600_000_000.0))])
    res = run_scenario(spec)
    ar = res.apps["kv"]
    pcts = percentiles(ar.latencies)
    return {"theta": theta, "completed": ar.completed,
            "p50_us": pcts["p50"], "p99_us": pcts["p99"]}


def _cross_shard_point(n_tx: int = 200) -> dict:
    """Commit latency of 2-shard MSETs vs single-shard, plus aborts under
    contention (all transactions fight over one small key set)."""
    from repro.core.substrate import Substrate
    from repro.service import ShardedService

    sub = Substrate(f_m=1, n_pools=N_POOLS, seed=3)
    svc = ShardedService.attach(sub, n_shards=2, cfg=_cfg())
    cl = svc.new_client()
    keys = [b"x%03d" % i for i in range(64)]
    s0 = [k for k in keys if svc.router.shard_of(k) == 0]
    s1 = [k for k in keys if svc.router.shard_of(k) == 1]

    single, cross, aborts = [], [], 0
    for i in range(n_tx):
        pairs_1 = [(s0[i % len(s0)], b"a%d" % i),
                   (s0[(i + 1) % len(s0)], b"b%d" % i)]
        r, lat = svc.run_op(cl, ("mset", pairs_1))
        assert r == b"OK", r
        single.append(lat)
        pairs_2 = [(s0[i % 4], b"c%d" % i), (s1[i % 4], b"d%d" % i)]
        r, lat = svc.run_op(cl, ("mset", pairs_2), timeout=2_000_000.0)
        if r == b"ABORTED":
            aborts += 1
        else:
            assert r == b"OK", r
            cross.append(lat)
    return {"n_tx": n_tx, "aborts": aborts,
            "single_shard_p50_us": percentiles(single)["p50"],
            "cross_shard_p50_us": percentiles(cross)["p50"],
            "cross_shard_p99_us": percentiles(cross)["p99"]}


def _split_run(do_split: bool, duration_us: float, late_us: float,
               split_at_us: float, seed: int = 5) -> dict:
    """One open-loop Zipf run under a ramping rate; optionally split the
    hot shard mid-run.

    The arrival schedule (times, keys, client assignment) is generated
    up-front from a fixed RNG, so the static and split runs see a
    byte-identical offered load — the only difference is the reshard.
    The rate ramps linearly from ``SPLIT_RATE0_RPS`` to ``SPLIT_RATE1_RPS``
    over the run (an inhomogeneous Poisson process, drawn by inverting
    the cumulative intensity): the split fires while the hot shard is
    still healthy, and the static arm is carried past its saturation
    cliff by the rush.
    """
    from repro.core.substrate import Substrate
    from repro.service import ShardedService

    sub = Substrate(f_m=1, n_pools=N_POOLS, seed=seed)
    svc = ShardedService.attach(sub, n_shards=KNEE_K, cfg=_cfg())

    # the ramp is the workload library's flash-crowd ramp (one
    # implementation; ramp_times draws exactly the exponential vector the
    # hand-rolled recipe did, so the schedule is byte-identical)
    rng = np.random.default_rng(11)
    times = ramp_times(rng, SPLIT_RATE0_RPS, SPLIT_RATE1_RPS, duration_us)
    n_ops = len(times)
    p = np.arange(1, KEYSPACE + 1, dtype=float) ** -SPLIT_THETA
    key_idx = rng.choice(KEYSPACE, size=n_ops, p=p / p.sum())
    keys = [b"k%03d" % i for i in key_idx]
    home = {k: svc.router.shard_of(k) for k in set(keys)}
    by_shard: dict = {}
    for k in keys:
        by_shard[home[k]] = by_shard.get(home[k], 0) + 1
    hot = max(by_shard, key=by_shard.get)

    clients = [svc.new_client() for _ in range(CLIENTS_PER_SHARD)]
    samples: list = []          # (issue_time, initial_shard, latency)

    def issue(i: int, t: float, k: bytes) -> None:
        def done(result: bytes, lat: float) -> None:
            samples.append((t, home[k], lat))
        clients[i % len(clients)].request(("set", k, b"v%d" % i), done)

    for i, (t, k) in enumerate(zip(times, keys)):
        sub.sim.at(float(t), lambda i=i, t=float(t), k=k: issue(i, t, k))
    split_done: dict = {}
    if do_split:
        sub.sim.at(split_at_us, lambda: svc.split_shard(
            hot, when_done=lambda: split_done.setdefault("t", sub.sim.now)))
    ok = sub.sim.run_until(lambda: len(samples) == n_ops,
                           timeout=duration_us + 2_000_000.0)
    assert ok, f"only {len(samples)}/{n_ops} ops completed"
    if do_split:
        assert split_done and split_done["t"] < late_us, \
            f"split not settled before the late window: {split_done}"
        assert svc.router.n_shards == KNEE_K + 1

    late_hot = [lat for (t, s, lat) in samples if t >= late_us and s == hot]
    assert late_hot, "no late-window hot-shard samples"
    pcts = percentiles(late_hot)
    return {"hot_shard": hot, "hot_share": by_shard[hot] / n_ops,
            "n_ops": n_ops, "split_done_us": split_done.get("t"),
            "late_hot_p50_us": pcts["p50"], "late_hot_p99_us": pcts["p99"]}


def _split_point(duration_us: float = SPLIT_DURATION_US,
                 late_us: float = SPLIT_LATE_US,
                 split_at_us: float = SPLIT_AT_US, min_gain: float = 3.0
                 ) -> dict:
    static = _split_run(False, duration_us, late_us, split_at_us)
    live = _split_run(True, duration_us, late_us, split_at_us)
    gain = static["late_hot_p99_us"] / max(live["late_hot_p99_us"], 1e-9)
    out = {"static": static, "split": live, "hot_p99_gain": gain}
    emit("sharded.split.hot_p99_gain", gain,
         f"static={static['late_hot_p99_us']:.1f}us_"
         f"split={live['late_hot_p99_us']:.1f}us_"
         f"done_at={live['split_done_us']:.0f}us")
    assert gain >= min_gain, (
        f"mid-run hot-shard split improved late-window p99 only "
        f"{gain:.2f}x (static {static['late_hot_p99_us']:.1f}us vs "
        f"split {live['late_hot_p99_us']:.1f}us)")
    return out


def run(scale_sweep=SCALE_SWEEP, thetas=THETAS, smoke: bool = False) -> dict:
    tune_runtime()
    out: dict = {"scaling": {}, "zipf": {}}

    for k in scale_sweep:
        row = _scale_point(k)
        out["scaling"][str(k)] = row
        emit(f"sharded.K{k}.tput_kops", row["tput_kops"],
             f"p50={row['p50_us']:.1f}us_p99={row['p99_us']:.1f}us")
    lo = out["scaling"].get("1")
    hi = out["scaling"].get(str(max(scale_sweep)))
    if lo and hi:
        speedup = hi["tput_kops"] / max(lo["tput_kops"], 1e-9)
        out["scaling_speedup"] = speedup
        emit("sharded.scaling.speedup", speedup,
             f"K=1:{lo['tput_kops']:.0f}kops_K={max(scale_sweep)}:"
             f"{hi['tput_kops']:.0f}kops")
        if max(scale_sweep) >= 4:
            assert speedup >= 3.0, (
                f"aggregate throughput scaled only {speedup:.2f}x from K=1 "
                f"to K={max(scale_sweep)} at fixed per-shard config")

    for theta in thetas:
        row = _zipf_point(theta)
        out["zipf"][f"{theta:.1f}"] = row
        emit(f"sharded.zipf{theta:.1f}.p99_us", row["p99_us"],
             f"p50={row['p50_us']:.1f}us")
    base = out["zipf"].get("0.0")
    worst = out["zipf"].get(f"{max(thetas):.1f}")
    if base and worst:
        knee = worst["p99_us"] / max(base["p99_us"], 1e-9)
        out["zipf_knee_p99_ratio"] = knee
        emit("sharded.zipf.knee_p99_ratio", knee,
             f"uniform={base['p99_us']:.1f}us_theta{max(thetas):.1f}="
             f"{worst['p99_us']:.1f}us")
        # the knee must be *visible*: skew concentrates load on the hot
        # shard and its queueing shows up in the tail
        assert knee >= 2.0, (
            f"no hot-shard knee: p99 grew only {knee:.2f}x under "
            f"Zipf theta={max(thetas)}")

    out["cross_shard"] = _cross_shard_point()
    cs = out["cross_shard"]
    emit("sharded.cross_shard.p50_us", cs["cross_shard_p50_us"],
         f"single_shard={cs['single_shard_p50_us']:.1f}us_"
         f"aborts={cs['aborts']}/{cs['n_tx']}")

    if smoke:
        out["split"] = _split_point(duration_us=SMOKE_SPLIT_DURATION_US,
                                    late_us=SMOKE_SPLIT_LATE_US,
                                    split_at_us=SMOKE_SPLIT_AT_US,
                                    min_gain=1.5)
    else:
        out["split"] = _split_point()
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    run(scale_sweep=SMOKE_SCALE_SWEEP if smoke else SCALE_SWEEP,
        thetas=SMOKE_THETAS if smoke else THETAS, smoke=smoke)
    print("sharded: scaling + knee + cross-shard + split checks passed")
