"""Table 2: uBFT replica (local) and disaggregated memory usage for
different CTBcast tails t and request sizes.

Local memory is dominated by the preallocated wire buffers (t slots + t-deep
staging per connection, slot = max message size) plus consensus-window and
CTBcast bookkeeping.  Disaggregated memory stores only (id, signature,
32 B fingerprint) per register × 2 sub-registers × checksums — independent
of request size (paper: 20 KiB at t=16 → 162 KiB at t=128 per memory node).
"""

from __future__ import annotations

from benchmarks.common import closed_loop_cluster, emit
from repro.apps.flip import FlipApp
from repro.core.consensus import ConsensusConfig
from repro.core.smr import build_cluster

TAILS = (16, 32, 64, 128)


def run() -> dict:
    out = {}
    for size in (64, 2048):
        for t in TAILS:
            cfg = ConsensusConfig(t=t, window=256, max_request_bytes=size,
                                  slow_mode="always", ctb_fast_enabled=False)
            cluster = build_cluster(FlipApp, cfg=cfg)
            client = cluster.new_client()
            closed_loop_cluster(cluster, client, lambda i: b"x" * size,
                                3 * t, timeout=600_000_000)
            local = cluster.replicas[0].memory_bytes()
            # measured occupancy at one memory node + full-occupancy model
            meas = max(m.memory_bytes() for m in cluster.mem_nodes)
            regs = cluster.replicas[0].regs
            slot = regs.disaggregated_bytes_per_register()
            n = len(cluster.replicas)
            analytic = n * n * t * slot  # n instances × n owners × t regs
            out[(size, t)] = {"local": local["total"], "disagg_meas": meas,
                              "disagg_full": analytic}
            emit(f"table2.{size}B.t{t}.local_MiB", local["total"] / 2**20,
                 f"tb={local['tbcast_buffers'] / 2**20:.1f}MiB")
            emit(f"table2.{size}B.t{t}.disagg_KiB", analytic / 1024,
                 f"measured={meas / 1024:.1f}KiB")
    return out


if __name__ == "__main__":
    run()
