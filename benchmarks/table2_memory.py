"""Table 2: uBFT replica (local) and disaggregated memory usage for
different CTBcast tails t and request sizes.

Local memory is dominated by the preallocated wire buffers (t slots + t-deep
staging per connection, slot = max message size) plus consensus-window and
CTBcast bookkeeping.  Disaggregated memory stores only (id, signature,
32 B fingerprint) per register × 2 sub-registers × checksums — independent
of request size (paper: 20 KiB at t=16 → 162 KiB at t=128 per memory node).

Pool accounting: the TCB is organised into pools of 2f_m+1 memory nodes
(``repro.core.registers.MemoryPool``); every pool must stay under 1 MiB of
occupied disaggregated memory (the Table 2 budget that lets many replicated
applications share one pool).  The sharding sweep shows per-pool occupancy
dropping as register keys spread over more pools.
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.apps.flip import FlipApp
from repro.core.consensus import ConsensusConfig
from repro.core.registers import POOL_MEMORY_BUDGET as POOL_BUDGET
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario

TAILS = (16, 32, 64, 128)


def _pool_bytes(cluster) -> int:
    """Worst-case occupied disaggregated memory over the cluster's pools."""
    return max(p.memory_bytes() for p in cluster.pools)


def _run_spec(cfg, size: int, n_reqs: int, n_pools: int = 1):
    res = run_scenario(ScenarioSpec(
        n_pools=n_pools,
        apps=[AppSpec(name="", app=FlipApp, cfg=cfg,
                      workload=Workload(kind="closed", n_requests=n_reqs,
                                        payload=b"x" * size,
                                        timeout_us=600_000_000))]))
    return res.clusters[""]


def run() -> dict:
    out = {}
    for size in (64, 2048):
        for t in TAILS:
            cfg = ConsensusConfig(t=t, window=256, max_request_bytes=size,
                                  slow_mode="always", ctb_fast_enabled=False)
            cluster = _run_spec(cfg, size, 3 * t)
            local = cluster.replicas[0].memory_bytes()
            # measured occupancy at one memory node / one pool + model
            meas = max(m.memory_bytes() for m in cluster.mem_nodes)
            pool = _pool_bytes(cluster)
            assert pool < POOL_BUDGET, (
                f"Table 2 bound violated: {pool} B occupied in one pool")
            regs = cluster.replicas[0].regs
            slot = regs.disaggregated_bytes_per_register()
            n = len(cluster.replicas)
            analytic = n * n * t * slot  # n instances × n owners × t regs
            out[(size, t)] = {"local": local["total"], "disagg_meas": meas,
                              "disagg_pool": pool, "disagg_full": analytic}
            emit(f"table2.{size}B.t{t}.local_MiB", local["total"] / 2**20,
                 f"tb={local['tbcast_buffers'] / 2**20:.1f}MiB")
            emit(f"table2.{size}B.t{t}.disagg_KiB", analytic / 1024,
                 f"measured={meas / 1024:.1f}KiB")
            emit(f"table2.{size}B.t{t}.disagg_pool_KiB", pool / 1024,
                 f"budget={POOL_BUDGET / 1024:.0f}KiB")

    # sharding sweep: per-pool occupancy under the largest tail as register
    # keys spread over more pools (paper: memory "shared by many replicated
    # applications" — a pool must never become the bottleneck)
    t = TAILS[-1]
    for n_pools in (1, 2, 4):
        cfg = ConsensusConfig(t=t, window=256, max_request_bytes=64,
                              slow_mode="always", ctb_fast_enabled=False)
        cluster = _run_spec(cfg, 64, 3 * t, n_pools=n_pools)
        pool = _pool_bytes(cluster)
        assert pool < POOL_BUDGET
        out[("shard", n_pools)] = {"disagg_pool": pool}
        emit(f"table2.shard.p{n_pools}.disagg_pool_KiB", pool / 1024,
             f"pools={n_pools}")
    return out


if __name__ == "__main__":
    run()
