"""Many replicated applications sharing one disaggregated-memory substrate.

The paper's economic argument (§ Abstract, §8) is that uBFT's TCB — "a
small amount of reliable disaggregated memory" — is *shared by many
replicated applications*.  This sweep makes that claim measurable: N
independent 2f+1 kvstore clusters attach to ONE substrate (one event loop,
one network, one set of memory pools) and run concurrent open-loop
workloads.  Open loop matters here: a closed loop would self-throttle as
the shared pools queue, hiding exactly the interference this benchmark
exists to expose.

Reported per sweep point (N = 1..8 apps):

* per-app p50/p99 latency — cross-app interference at the shared memory
  nodes shows up as the tail growing with N;
* per-app occupied disaggregated memory per pool (Table 2 split per app) —
  asserted < 1 MiB per app per pool, and zero per-app budget overruns
  recorded by the substrate audit.

The workload keeps the slow path on (``slow_mode="always"``) so every slot
crosses the disaggregated registers that all apps share.

Usage:  PYTHONPATH=src:. python benchmarks/shared_pools.py [--smoke]
"""

from __future__ import annotations

import sys

from benchmarks.common import emit, percentiles, tune_runtime
from repro.apps.kvstore import KVStoreApp, set_req
from repro.core.consensus import ConsensusConfig
from repro.core.registers import POOL_MEMORY_BUDGET
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario

N_POOLS = 2
DURATION_US = 3_000.0
RATE_RPS = 10_000.0          # per app: near the batched slow-path knee
SWEEP = (1, 2, 4, 8)
SMOKE_SWEEP = (1, 4)


def _cfg() -> ConsensusConfig:
    # batched slots keep a single app below saturation, so whatever tail
    # growth the sweep shows is *cross-app* queueing at the shared memory
    # nodes, not an app self-saturating its own leader
    return ConsensusConfig(t=16, window=32, slow_mode="always",
                           ctb_fast_enabled=False, max_batch=8,
                           pipeline_depth=4, view_timeout_us=40_000.0)


def _payload_fn(app_idx: int):
    def payload(i: int) -> bytes:
        return set_req(b"k%d.%d" % (app_idx, i % 8), b"v%d" % i)
    return payload


def run(sweep=SWEEP) -> dict:
    tune_runtime()
    out: dict = {}
    for n_apps in sweep:
        spec = ScenarioSpec(
            n_pools=N_POOLS, seed=0,
            apps=[AppSpec(name=f"app{i}", app=KVStoreApp, cfg=_cfg(),
                          workload=Workload(kind="open", rate_rps=RATE_RPS,
                                            duration_us=DURATION_US,
                                            payload_fn=_payload_fn(i),
                                            seed=1000 + i,
                                            timeout_us=600_000_000))
                  for i in range(n_apps)])
        res = run_scenario(spec)

        assert not res.budget_overruns, (
            f"per-app Table 2 budget overrun on the shared substrate: "
            f"{res.budget_overruns}")
        row: dict = {"apps": {}}
        worst_p99 = 0.0
        worst_app_pool = 0
        for name, ar in sorted(res.apps.items()):
            assert ar.completed == ar.issued, (name, ar.completed, ar.issued)
            pcts = percentiles(ar.latencies)
            app_pool_max = max(ar.memory_by_pool.values(), default=0)
            # the Table 2 budget, asserted PER APP on the shared pools
            assert app_pool_max < POOL_MEMORY_BUDGET, (
                f"{name} occupies {app_pool_max} B in one shared pool")
            row["apps"][name] = {
                "n": ar.completed, "p50_us": pcts["p50"],
                "p99_us": pcts["p99"],
                "pool_bytes_max": app_pool_max,
                "pool_bytes": dict(ar.memory_by_pool),
            }
            worst_p99 = max(worst_p99, pcts["p99"])
            worst_app_pool = max(worst_app_pool, app_pool_max)
        # substrate-level rollup
        row["pool_bytes_total"] = {p.name: p.memory_bytes()
                                   for p in res.substrate.pools}
        row["msgs_sent"] = res.msgs_sent
        row["events"] = res.events_processed
        out[n_apps] = row

        a0 = row["apps"]["app0"]
        emit(f"shared.{n_apps}apps.app0.p50_us", a0["p50_us"])
        emit(f"shared.{n_apps}apps.app0.p99_us", a0["p99_us"],
             f"worst_app_p99={worst_p99:.1f}us")
        emit(f"shared.{n_apps}apps.per_app_pool_KiB",
             worst_app_pool / 1024,
             f"budget={POOL_MEMORY_BUDGET / 1024:.0f}KiB_per_app")

    # interference headline: how much does app0's tail grow when 7
    # neighbours share its substrate?
    if 1 in out and max(sweep) in out:
        lo = out[1]["apps"]["app0"]["p99_us"]
        hi = out[max(sweep)]["apps"]["app0"]["p99_us"]
        out["p99_interference"] = hi / max(lo, 1e-9)
        emit("shared.interference.p99_ratio", out["p99_interference"],
             f"1app={lo:.1f}us vs {max(sweep)}apps={hi:.1f}us")
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    run(sweep=SMOKE_SWEEP if smoke else SWEEP)
    print("shared_pools: all per-app budget checks passed")
