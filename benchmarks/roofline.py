"""Roofline analysis (deliverable g): three-term roofline per
(architecture × shape × mesh) from the dry-run artifacts.

    compute    = HLO_FLOPs_per_dev / peak_FLOP/s      (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_dev / HBM_bw           (819 GB/s)
    collective = link_bytes_per_dev / link_bw         (50 GB/s/link ICI)

(cost_analysis reports post-SPMD per-device numbers, so the per-chip form of
the assignment's formulas is used directly.)  MODEL_FLOPS = 6·N·D for
training (2·N·D prefill, 2·N·B decode), N_active for MoE.  Writes
artifacts/roofline.csv + .md and prints summary rows.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")

_IMPROVE = {
    "compute": "reduce recompute (remat policy) / increase arithmetic "
               "intensity per chip",
    "memory": "cut activation traffic: fuse softmax/norm chains, bf16 "
              "logits, larger per-chip tiles",
    "collective": "reshard to cut all-gathers (FSDP prefetch), overlap "
                  "collectives with compute, gradient compression",
}


def _model_flops(arch: str, shape: str) -> Optional[float]:
    import jax
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, params_spec
    cfg = get_config(arch)
    shapes = params_spec(cfg)
    n_total = 0
    n_moe = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        sz = 1
        for d in leaf.shape:
            sz *= d
        n_total += sz
        name = None
        for e in reversed(path):
            if hasattr(e, "key"):
                name = e.key
                break
        if name in ("w_gate", "w_up", "w_down") and len(leaf.shape) == 4:
            n_moe += sz
    n_active = n_total
    if cfg.moe is not None and n_moe:
        n_active = n_total - n_moe * (1.0 - cfg.moe.top_k / cfg.moe.n_experts)
    sp = SHAPES[shape]
    if sp.kind == "train":
        return 6.0 * n_active * sp.batch * sp.seq
    if sp.kind == "prefill":
        return 2.0 * n_active * sp.batch * sp.seq
    return 2.0 * n_active * sp.batch     # decode: one token per sequence


def run() -> Dict:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun", "*.json"))):
        r = json.load(open(path))
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "skipped",
                         "note": r.get("reason", "")})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": r.get("status"),
                         "note": str(r.get("error", ""))[:90]})
            continue
        c = r.get("corrected") or r["raw"]
        chips = 512 if r["mesh"] == "pod2x16x16" else 256
        t_comp = c["flops"] / PEAK_FLOPS
        t_mem = c["bytes"] / HBM_BW
        link = c["collectives"].get("total_link", 0.0)
        t_coll = link / LINK_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        mf = _model_flops(r["arch"], r["shape"])
        hlo_global = c["flops"] * chips
        ratio = mf / hlo_global if hlo_global else 0.0
        frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok", "compute_s": t_comp, "memory_s": t_mem,
            "collective_s": t_coll, "dominant": dom,
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "useful_ratio": ratio, "roofline_fraction": frac,
            "hbm_fit": r["memory"]["total_hbm_bytes"] < 16e9,
            "note": _IMPROVE[dom],
        })
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    # CSV + printed summary
    for row in rows:
        if row["status"] != "ok":
            print(f"roofline.{row['arch']}.{row['shape']}.{row['mesh']},0,"
                  f"{row['status']}:{row['note']}")
            continue
        print(f"roofline.{row['arch']}.{row['shape']}.{row['mesh']},"
              f"{max(row['compute_s'], row['memory_s'], row['collective_s']) * 1e6:.1f},"
              f"dom={row['dominant']};frac={row['roofline_fraction']:.3f};"
              f"useful={row['useful_ratio']:.2f}")
    return {"rows": rows}


if __name__ == "__main__":
    run()
