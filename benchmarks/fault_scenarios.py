"""Seeded fault-schedule scenario sweep over the TCB (memory pools).

Drives a kvstore uBFT cluster (2 sharded memory pools) through the
deterministic fault schedules of ``repro.sim.faults`` — memory-node
crashes, lease-based pool reconfiguration, replica+memory double faults,
and partition+heal episodes — and reports per-scenario client latency,
fault logs, and per-pool disaggregated-memory occupancy (must stay under
the 1 MiB Table 2 budget).  Every run also re-checks the safety
invariants: all acknowledged writes present on every live replica, no
divergence between replica stores.

A final *chatter gate* replays a replica crash+recover episode and then
measures network-wide idle message counts: retransmission towards the
recovered replica must quiesce (ISSUE 7), not ping at the rto forever.

Usage:  PYTHONPATH=src:. python benchmarks/fault_scenarios.py [--smoke]
"""

from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, percentiles
from repro.apps.kvstore import KVStoreApp, set_req
from repro.core.consensus import ConsensusConfig
from repro.core.registers import POOL_MEMORY_BUDGET as POOL_BUDGET
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario
from repro.sim.faults import FaultSchedule

#: scenario name -> schedule builder(seed, substrate) — all registers-heavy
#: (slow_mode="always" keeps the disaggregated-memory path hot).
SCENARIOS = {}


def scenario(name):
    def deco(fn):
        SCENARIOS[name] = fn
        return fn
    return deco


@scenario("mem_crash")
def _mem_crash(seed, substrate):
    """Crash f_m memory nodes (one per pool), later recover them."""
    return FaultSchedule.seeded(
        seed, horizon_us=4000.0, memory=["m0", "p1m1"],
        pools=substrate.pools, n_memory_crashes=2, recover=True)


@scenario("reconfig")
def _reconfig(seed, substrate):
    """Crash one memory node mid-broadcast and reconfigure its pool."""
    return FaultSchedule.seeded(
        seed, horizon_us=4000.0, memory=["m0"], pools=substrate.pools,
        n_memory_crashes=1, reconfigure=True)


@scenario("replica_plus_mem")
def _replica_plus_mem(seed, substrate):
    """A follower replica crash on top of a memory-node crash."""
    return FaultSchedule.seeded(
        seed, horizon_us=4000.0, memory=["m1"], pools=substrate.pools,
        replicas=["r2"], n_memory_crashes=1, n_replica_crashes=1,
        reconfigure=True)


@scenario("partition_heal")
def _partition_heal(seed, substrate):
    """Partition a replica pair, heal before the view times out."""
    return FaultSchedule.seeded(
        seed, horizon_us=3000.0, partitions=[("r1", "r2")], n_partitions=1)


def _check_safety(cluster, acked):
    alive = [r for r in cluster.replicas if not r.crashed]
    cluster.sim.run(until=cluster.sim.now + 100_000)
    for rep in alive:
        for k, v in acked.items():
            assert rep.app.store.get(k) == v, (rep.pid, k)
    for a, b in zip(alive, alive[1:]):
        assert a.app.store == b.app.store
    for p in cluster.pools:
        assert p.memory_bytes() < POOL_BUDGET, p.name


def _chatter_point(seed: int = 9, n_reqs: int = 12) -> dict:
    """Regression gate for ISSUE 7's quiesce bug: after a replica
    crash+recover episode the cluster must go *quiet* — TBcast
    retransmission towards the recovered replica has to drain once it
    re-acks, instead of pinging every rto forever.  Measures the
    network-wide message count over two idle windows long after the
    workload completes; the second window must not exceed the first
    (steady-state background only) and must stay under an absolute lid.
    """
    cfg = ConsensusConfig(t=16, window=16, slow_mode="always",
                          ctb_fast_enabled=False,
                          view_timeout_us=20_000.0)
    acked = {}

    def payload(i):
        k, v = b"k%d" % (i % 8), b"v%d" % i
        acked[k] = v
        return set_req(k, v)

    res = run_scenario(ScenarioSpec(
        n_pools=2, seed=seed,
        faults=lambda substrate: (FaultSchedule()
                                  .add(800.0, "crash", "r2")
                                  .add(2000.0, "recover", "r2")),
        apps=[AppSpec(name="", app=KVStoreApp, cfg=cfg,
                      workload=Workload(kind="closed", n_requests=n_reqs,
                                        payload_fn=payload,
                                        timeout_us=600_000_000))]))
    cluster = res.clusters[""]
    _check_safety(cluster, acked)
    sim, net = cluster.sim, cluster.net
    sim.run(until=sim.now + 200_000.0)       # settle past any backoff tail
    windows = []
    for _ in range(2):
        before = net.msgs_sent
        sim.run(until=sim.now + 100_000.0)
        windows.append(net.msgs_sent - before)
    w1, w2 = windows
    emit("faults.chatter.idle_msgs_per_100ms", w2, f"w1={w1}")
    assert w2 <= max(w1, 8), (
        f"idle chatter still growing after crash+recover: "
        f"window1={w1} window2={w2} msgs/100ms")
    assert w2 <= 50, (
        f"idle chatter too high after crash+recover: {w2} msgs/100ms — "
        f"retransmission towards the recovered replica did not quiesce")
    return {"idle_window1_msgs": w1, "idle_window2_msgs": w2}


def run(seeds=(0, 1, 2), n_reqs=40) -> dict:
    out = {}
    for name, make in SCENARIOS.items():
        for seed in seeds:
            cfg = ConsensusConfig(t=16, window=16, slow_mode="always",
                                  ctb_fast_enabled=False,
                                  view_timeout_us=20_000.0)
            acked = {}

            def payload(i):
                k, v = b"k%d" % (i % 8), b"v%d" % i
                acked[k] = v
                return set_req(k, v)

            res = run_scenario(ScenarioSpec(
                n_pools=2, seed=seed,
                faults=lambda substrate: make(seed, substrate),
                apps=[AppSpec(name="", app=KVStoreApp, cfg=cfg,
                              workload=Workload(kind="closed",
                                                n_requests=n_reqs,
                                                payload_fn=payload,
                                                timeout_us=600_000_000))]))
            cluster = res.clusters[""]
            _check_safety(cluster, acked)
            pool = max(p.memory_bytes() for p in cluster.pools)
            reconf = sum(len(p.reconfigurations) for p in cluster.pools)
            pcts = percentiles(res.latencies())
            out[(name, seed)] = {"p50": pcts["p50"], "p99": pcts["p99"],
                                 "faults": len(res.injector.log),
                                 "reconf": reconf, "pool_bytes": pool}
            emit(f"faults.{name}.s{seed}.p50", pcts["p50"],
                 f"p99={pcts['p99']:.1f} faults={len(res.injector.log)} "
                 f"reconf={reconf} pool={pool / 1024:.1f}KiB")
    out[("chatter", 9)] = _chatter_point()
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    run(seeds=(0,) if smoke else (0, 1, 2), n_reqs=20 if smoke else 40)
    print("fault_scenarios: all safety checks passed")
