"""Figure 11: uBFT fast-path tail latency vs CTBcast tail parameter t, for
64 B and 2 KiB requests.

Paper behaviour: small t → the broadcaster fills both summary double-buffers
before certification completes and stalls ("thrashing"); the latency spike
appears at lower percentiles for smaller t; t=128 is clean to p99 for 64 B;
t=64 suffices for 2 KiB.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.apps.flip import FlipApp
from repro.core.consensus import ConsensusConfig
from repro.scenario import AppSpec, ScenarioSpec, Workload, run_scenario

TAILS = (16, 32, 64, 128)
N = 1200


def run() -> dict:
    out = {}
    for size in (64, 2048):
        payload = b"x" * size
        for t in TAILS:
            cfg = ConsensusConfig(t=t, window=256)
            res = run_scenario(ScenarioSpec(apps=[AppSpec(
                name="", app=FlipApp, cfg=cfg,
                workload=Workload(kind="closed", n_requests=N,
                                  payload=payload,
                                  timeout_us=120_000_000))]))
            lats = np.asarray(res.latencies())
            stalls = sum(r.my_ctb.stall_count
                         for r in res.clusters[""].replicas)
            row = {f"p{p}": float(np.percentile(lats, p))
                   for p in (50, 90, 99, 99.9)}
            row["stalls"] = stalls
            out[(size, t)] = row
            emit(f"fig11.{size}B.t{t}.p50", row["p50"])
            emit(f"fig11.{size}B.t{t}.p99", row["p99"],
                 f"p99.9={row['p99.9']:.1f};stalls={stalls}")
    return out


if __name__ == "__main__":
    run()
