"""Figure 11: uBFT fast-path tail latency vs CTBcast tail parameter t, for
64 B and 2 KiB requests.

Paper behaviour: small t → the broadcaster fills both summary double-buffers
before certification completes and stalls ("thrashing"); the latency spike
appears at lower percentiles for smaller t; t=128 is clean to p99 for 64 B;
t=64 suffices for 2 KiB.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import closed_loop_cluster, emit
from repro.apps.flip import FlipApp
from repro.core.consensus import ConsensusConfig
from repro.core.smr import build_cluster

TAILS = (16, 32, 64, 128)
N = 1200


def run() -> dict:
    out = {}
    for size in (64, 2048):
        payload = b"x" * size
        for t in TAILS:
            cfg = ConsensusConfig(t=t, window=256)
            cluster = build_cluster(FlipApp, cfg=cfg)
            client = cluster.new_client()
            lats = np.asarray(closed_loop_cluster(
                cluster, client, lambda i: payload, N,
                timeout=120_000_000))
            stalls = sum(r.my_ctb.stall_count for r in cluster.replicas)
            row = {f"p{p}": float(np.percentile(lats, p))
                   for p in (50, 90, 99, 99.9)}
            row["stalls"] = stalls
            out[(size, t)] = row
            emit(f"fig11.{size}B.t{t}.p50", row["p50"])
            emit(f"fig11.{size}B.t{t}.p99", row["p99"],
                 f"p99.9={row['p99.9']:.1f};stalls={stalls}")
    return out


if __name__ == "__main__":
    run()
