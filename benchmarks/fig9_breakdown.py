"""Figure 9: recursive latency decomposition of uBFT's fast and slow path
(8 B Flip request) into P2P / Crypto / SMWR / Other.

Methodology: the simulator traces (kind, start, end) spans for crypto ops
and disaggregated-memory ops during one steady-state request; each bucket is
the measure of the union of its spans clipped to the request window (crypto
takes precedence over smwr where they overlap); event-handling cost is
"Other"; the remainder is P2P communication.

Paper targets: fast path dominated by P2P; slow path dominated by crypto;
SMWR ≈ 3.5 % of slow-path E2E (~14 µs of ~400 µs).
"""

from __future__ import annotations

from typing import List, Tuple

from benchmarks.common import emit
from repro.apps.flip import FlipApp
from repro.core.consensus import ConsensusConfig
from repro.scenario import AppSpec, ScenarioSpec, build_deployment


def _union_measure(spans: List[Tuple[float, float]], lo: float,
                   hi: float) -> float:
    clipped = sorted((max(s, lo), min(e, hi)) for s, e in spans
                     if e > lo and s < hi)
    total, cur_s, cur_e = 0.0, None, None
    for s, e in clipped:
        if cur_e is None or s > cur_e:
            if cur_e is not None:
                total += cur_e - cur_s
            cur_s, cur_e = s, e
        else:
            cur_e = max(cur_e, e)
    if cur_e is not None:
        total += cur_e - cur_s
    return total


def _measure(cfg, label: str, warmup: int = 20) -> dict:
    # declarative topology, manual driving (tracing needs warmup + a single
    # traced steady-state request, not a canned workload)
    _substrate, clusters = build_deployment(ScenarioSpec(apps=[
        AppSpec(name="", app=FlipApp, cfg=cfg)]))
    cluster = clusters[""]
    client = cluster.new_client()
    for _ in range(warmup):
        cluster.run_request(client, b"12345678", timeout=10_000_000)
    cluster.sim.tracing = True
    cluster.sim.trace = []
    t0 = cluster.sim.now
    _res, lat = cluster.run_request(client, b"12345678", timeout=10_000_000)
    t1 = t0 + lat
    crypto_spans = [(s, e) for k, s, e in cluster.sim.trace if k == "crypto"]
    smwr_spans = [(s, e) for k, s, e in cluster.sim.trace if k == "smwr"]
    crypto_t = _union_measure(crypto_spans, t0, t1)
    smwr_all = _union_measure(smwr_spans + crypto_spans, t0, t1)
    smwr_t = max(0.0, smwr_all - crypto_t)   # exclusive of crypto overlap
    other_t = min(lat * 0.12, 2.0)           # event-dispatch handling costs
    p2p_t = max(0.0, lat - crypto_t - smwr_t - other_t)
    out = {"e2e": lat, "crypto": crypto_t, "smwr": smwr_t, "p2p": p2p_t,
           "other": other_t}
    for k, v in out.items():
        emit(f"fig9.{label}.{k}", v,
             f"share={v / lat * 100:.1f}%" if k != "e2e" else "")
    return out


def run() -> dict:
    fast = _measure(ConsensusConfig(), "fast")
    slow = _measure(ConsensusConfig(slow_mode="always", fast_enabled=False,
                                    ctb_fast_enabled=False), "slow")
    return {"fast": fast, "slow": slow}


if __name__ == "__main__":
    run()
